//! L3 serving coordinator: request router + sharded executor pool +
//! lane-aware dynamic signature batcher.
//!
//! The paper's contribution lives in the generation pipeline (L2/L1), so
//! per DESIGN.md the coordinator is the serving shell around the compiled
//! operators: it routes attention requests across executor shards
//! (family→shard affinity with load-aware rebalancing), packs
//! same-signature requests into batched executions per prefill/decode
//! lane (vLLM-style, specialized to fixed-shape executables), reports
//! latency / throughput / occupancy metrics, and feeds measured
//! per-variant latencies back into the autotuner's `TuneCache`.
//!
//! Observability (DESIGN.md §11): `tlc serve --trace-out <path>` turns
//! span tracing on and writes a Chrome-trace JSON of the request
//! lifecycle on shutdown, `--metrics-out <path>` writes the Prometheus
//! exposition ([`metrics_exposition`]) and `--stats-every <n>` flushes
//! a summary line (and refreshes the metrics file) every `n` executed
//! batches while the stream is in flight.
//!
//! Fault tolerance (DESIGN.md §13): requests carry deadlines and a
//! bounded retry budget, shard threads are supervised (crashed shards
//! restart, hung shards are steered around and their work re-dispatched),
//! failing artifact variants are quarantined with graceful degradation
//! down to the bit-exact reference executor, and
//! `tlc serve --fault-plan ...` injects deterministic seeded faults for
//! the chaos tests and `benches/faults.rs`.

pub mod batcher;
pub mod faults;
pub mod metrics;
pub mod prefix;
pub mod quarantine;
pub mod request;
pub mod scheduler;
pub mod service;

pub use faults::{FaultPlan, FaultyExecutor};
pub use prefix::{PrefixCache, PrefixClaim};
pub use quarantine::QuarantineBoard;
pub use request::{AttnRequest, AttnResponse, FamilyKey, LaneKey, ReplySlot, RequestOutcome};
pub use scheduler::{
    BatchKv, Executor, ExecutorSpec, PoolOptions, RetryPolicy, Router, ServeTopology,
    SupervisorConfig,
};
pub use service::{Coordinator, ServeConfig};

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::util::cli::Args;

/// Prometheus text exposition for a serving run: the coordinator's
/// [`metrics::Metrics`] samples plus everything in the [`crate::obs`]
/// registry (per-lane queue depths, KV-pool residency).
pub fn metrics_exposition(metrics: &metrics::Metrics) -> String {
    let mut samples = metrics.samples();
    samples.extend(crate::obs::global().samples());
    crate::obs::export::prometheus_text(&samples)
}

/// Background flusher for `tlc serve --stats-every N`: watches the batch
/// counter and, each time it advances past another `N` batches, prints a
/// one-line metrics summary and (when configured) rewrites the
/// Prometheus exposition file in place — live visibility into a long
/// stream without touching the serve hot path.
struct StatsFlusher {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl StatsFlusher {
    fn start(
        metrics: Arc<metrics::Metrics>,
        every: usize,
        path: Option<PathBuf>,
    ) -> StatsFlusher {
        let stop = Arc::new(AtomicBool::new(false));
        let watcher_stop = stop.clone();
        let every = every.max(1) as u64;
        let handle = std::thread::spawn(move || {
            let mut flushed_bucket = 0u64;
            while !watcher_stop.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(20));
                let batches = metrics.batches.load(Ordering::Relaxed);
                if batches / every > flushed_bucket {
                    flushed_bucket = batches / every;
                    eprintln!("[stats @ {batches} batches] {}", metrics.summary());
                    if let Some(p) = &path {
                        let _ = std::fs::write(p, metrics_exposition(&metrics));
                    }
                }
            }
        });
        StatsFlusher { stop, handle: Some(handle) }
    }

    fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Outcome of a serving run (used by `tlc serve`, the E2E example and the
/// coordinator bench).
#[derive(Debug)]
pub struct ServeReport {
    pub requests: usize,
    pub ok: usize,
    pub errors: usize,
    /// Requests shed because their deadline passed.
    pub timeouts: usize,
    /// Requests answered by the degraded reference lane (bit-exact, but
    /// slower than a compiled variant).
    pub degraded: usize,
    pub wall: Duration,
    pub throughput_rps: f64,
    pub mean_latency: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub mean_occupancy: f64,
    pub metrics_summary: String,
}

/// Drive a synthetic request stream through a coordinator and collect the
/// report. Requests are submitted following their arrival offsets
/// (time-compressed by `speedup` — 1.0 replays in real time).
pub fn run_stream(
    coordinator: &Coordinator,
    stream: &[crate::workload::SyntheticRequest],
    speedup: f64,
) -> ServeReport {
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(stream.len());
    for req in stream {
        let due = Duration::from_secs_f64(req.arrival.as_secs_f64() / speedup);
        if let Some(wait) = due.checked_sub(t0.elapsed()) {
            if !wait.is_zero() {
                std::thread::sleep(wait);
            }
        }
        let (q, k, v) = req.payload();
        rxs.push(coordinator.submit(req.family.clone(), q, k, v));
    }
    let mut ok = 0;
    let mut errors = 0;
    let mut timeouts = 0;
    let mut degraded = 0;
    for rx in rxs {
        match rx.recv() {
            Ok(resp) => {
                if resp.degraded {
                    degraded += 1;
                }
                match resp.outcome {
                    request::RequestOutcome::Ok(_) => ok += 1,
                    request::RequestOutcome::Timeout => timeouts += 1,
                    request::RequestOutcome::Failed(_) => errors += 1,
                }
            }
            // A disconnected reply channel means the pool died without a
            // terminal response — counted as an error (the exactly-once
            // chaos test asserts this never happens).
            Err(_) => errors += 1,
        }
    }
    let wall = t0.elapsed();
    let m = &coordinator.metrics;
    ServeReport {
        requests: stream.len(),
        ok,
        errors,
        timeouts,
        degraded,
        wall,
        throughput_rps: ok as f64 / wall.as_secs_f64(),
        mean_latency: m.mean_latency().unwrap_or_default(),
        p50: m.latency_percentile(0.5).unwrap_or_default(),
        p95: m.latency_percentile(0.95).unwrap_or_default(),
        mean_occupancy: m.mean_occupancy(),
        metrics_summary: m.summary(),
    }
}

/// `tlc serve`: stand up the coordinator on the AOT artifacts (or the
/// reference executor) and push a synthetic stream through it.
pub fn cli_serve(args: &Args) -> Result<(), String> {
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let n = args.get_usize("requests", 64)?;
    let rate = args
        .get("rate-hz")
        .map(|v| v.parse::<f64>().map_err(|_| "bad --rate-hz".to_string()))
        .transpose()?
        .unwrap_or(200.0);
    let window_ms = args.get_usize("window-ms", 5)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let shards = args.get_usize("shards", 1)?;
    let decode_frac = args
        .get("decode-frac")
        .map(|v| v.parse::<f64>().map_err(|_| "bad --decode-frac".to_string()))
        .transpose()?
        .unwrap_or(0.0);
    if !(0.0..=1.0).contains(&decode_frac) {
        return Err("--decode-frac must be in [0, 1]".into());
    }
    let executor = match args.get_or("executor", "pjrt") {
        "pjrt" => ExecutorSpec::Pjrt,
        "reference" | "ref" => ExecutorSpec::Reference,
        other => return Err(format!("unknown --executor `{other}` (pjrt|reference)")),
    };
    let kv_budget_mb = args.get_usize("kv-budget-mb", 0)?;
    let decode_layout = crate::sketch::spec::kv_layout_from_cli(args)?;
    let metrics_out = args.get("metrics-out").map(PathBuf::from);
    let trace_out = args.get("trace-out").map(PathBuf::from);
    let stats_every = args.get_usize("stats-every", 0)?;
    let deadline_ms = args.get_usize("deadline-ms", 0)?;
    let max_attempts = args.get_usize("max-attempts", 0)?;
    let fault_plan = args.get("fault-plan").map(faults::FaultPlan::parse).transpose()?;
    let prefix_cache = args.get_bool("prefix-cache");
    let max_inflight = args.get_usize("max-inflight", 0)?;
    args.finish()?;

    if trace_out.is_some() {
        crate::obs::set_enabled(true);
    }

    let mut retry = RetryPolicy::default();
    if max_attempts > 0 {
        retry.max_attempts = max_attempts as u32;
    }
    if let Some(plan) = &fault_plan {
        println!("fault plan: {}", plan.render());
    }
    let coordinator = Coordinator::start(ServeConfig {
        artifacts_dir: artifacts,
        batch_window: Duration::from_millis(window_ms as u64),
        shards,
        executor,
        kv_budget_bytes: if kv_budget_mb == 0 { usize::MAX } else { kv_budget_mb << 20 },
        decode_layout,
        deadline: (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms as u64)),
        retry,
        fault_plan,
        prefix_cache,
        max_inflight,
        ..ServeConfig::default()
    })
    .map_err(|e| format!("{e:#}"))?;
    println!(
        "coordinator up: {} shard(s), {} servable attention families",
        coordinator.shards(),
        coordinator.families.len()
    );
    if coordinator.tuned_selections > 0 {
        println!(
            "tune cache selected {} artifact variant(s) (artifacts/tune.txt)",
            coordinator.tuned_selections
        );
    }
    let stream = crate::workload::request_stream_mixed(
        &coordinator.families,
        n,
        rate,
        decode_frac,
        seed,
    );
    let flusher = (stats_every > 0).then(|| {
        StatsFlusher::start(coordinator.metrics.clone(), stats_every, metrics_out.clone())
    });
    let report = run_stream(&coordinator, &stream, 1.0);
    if let Some(f) = flusher {
        f.stop();
    }
    println!(
        "served {} requests in {:.2?}: {} ok, {} errors, {} timeouts, {} degraded",
        report.requests, report.wall, report.ok, report.errors, report.timeouts, report.degraded
    );
    let restarts = coordinator.metrics.shard_restarts.load(std::sync::atomic::Ordering::Relaxed);
    let retries = coordinator.metrics.retries.load(std::sync::atomic::Ordering::Relaxed);
    if restarts > 0 || retries > 0 {
        println!("fault recovery: {restarts} shard restart(s), {retries} retried execution(s)");
    }
    if coordinator.quarantine.quarantined_count() > 0 {
        println!(
            "quarantined {} artifact variant(s): {}",
            coordinator.quarantine.quarantined_count(),
            coordinator.quarantine.quarantined().join(", ")
        );
    }
    println!(
        "throughput {:.1} req/s; latency mean {:.2?} p50 {:.2?} p95 {:.2?}; \
         mean batch occupancy {:.2}",
        report.throughput_rps,
        report.mean_latency,
        report.p50,
        report.p95,
        report.mean_occupancy
    );
    println!("{}", report.metrics_summary);
    if let Some(cache) = &coordinator.prefix {
        println!(
            "prefix cache: {} hit(s) / {} miss(es), {:.2} MiB shared, \
             {:.2} MiB materialized, {} eviction(s), peak {:.2} MiB resident",
            cache.hits(),
            cache.misses(),
            cache.shared_bytes_total() as f64 / (1 << 20) as f64,
            cache.new_bytes_total() as f64 / (1 << 20) as f64,
            cache.evictions(),
            cache.peak_bytes() as f64 / (1 << 20) as f64,
        );
    }
    if coordinator.kv_pool.peak_bytes() > 0 {
        println!(
            "kv pool ({}): peak {:.2} MiB resident, {} deferred batch(es)",
            decode_layout,
            coordinator.kv_pool.peak_bytes() as f64 / (1 << 20) as f64,
            coordinator.kv_pool.waits(),
        );
    }
    if let Some(snapshot) = coordinator.tune_snapshot() {
        if snapshot.observed_count() > 0 {
            println!(
                "tune cache: {} observed-latency entries folded in from serving",
                snapshot.observed_count()
            );
        }
    }
    if let Some(p) = &metrics_out {
        std::fs::write(p, metrics_exposition(&coordinator.metrics))
            .map_err(|e| format!("write {}: {e}", p.display()))?;
        println!("wrote Prometheus metrics -> {}", p.display());
    }
    if let Some(p) = &trace_out {
        let trace = crate::obs::export::chrome_trace(&crate::obs::global().spans());
        std::fs::write(p, trace).map_err(|e| format!("write {}: {e}", p.display()))?;
        println!("wrote Chrome trace -> {}", p.display());
    }
    coordinator.shutdown();
    Ok(())
}
