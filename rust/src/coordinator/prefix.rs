//! Copy-on-write shared-prefix KV cache for the decode lane.
//!
//! Requests in a serving fleet overwhelmingly share K/V prefixes — the
//! system prompt, a RAG context, or parallel samples from one decoding
//! session. The serving payload carries dense per-request K/V (there are
//! no token IDs at this layer), so prefixes are recognized *by content*:
//! the cache splits each request's K/V into page-granular runs, hashes
//! every page, and interns the pages into a radix tree whose edges are
//! page contents (hash-indexed, bitwise-verified — a hash collision can
//! never alias two different pages). Two requests with an identical
//! prefix walk the same path from the family root and map their block
//! tables onto the same physical pages.
//!
//! Accounting and lifecycle:
//!
//! * A shared page is charged against the byte budget **once**, no
//!   matter how many in-flight claims reference it.
//! * Every claim pins its chain (per-node refcounts), so a page can
//!   never be evicted or mutated while a batch reads it. Shared pages
//!   are read-only for their whole pinned lifetime — mutation goes
//!   through [`PrefixCache::cow_extend`], which copies a shared tail
//!   page before writing (copy-on-write).
//! * Releasing a claim unpins its chain but keeps the pages resident;
//!   eviction is LRU over refcount-0 childless runs, so a hot prefix
//!   interior is kept alive by its cached descendants.
//! * When the budget is exhausted and nothing is evictable, an intern
//!   whose pins are the only pins in the cache is admitted anyway —
//!   the same idle-admit progress guarantee as `PagedKvPool`, so one
//!   oversized sequence cannot deadlock the decode lane.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::request::FamilyKey;
use super::scheduler::lock;

/// Block-table entry for a padded (absent) slot.
pub const NO_PAGE: i64 = -1;

/// One interned page run: `rows` K/V rows stored head-major
/// (`[kv_heads][rows][dim]`), chained to the preceding page of its
/// sequence. Only full pages have children; a partial tail page is
/// always a leaf.
struct PageNode {
    family: FamilyKey,
    parent: Option<usize>,
    children: Vec<usize>,
    /// In-flight claims holding this page (pinned while > 0).
    refcount: u32,
    /// Logical clock at the last unpin — the LRU eviction key.
    last_release: u64,
    rows: usize,
    hash: u64,
    k: Vec<f32>,
    v: Vec<f32>,
    bytes: usize,
}

struct Inner {
    nodes: Vec<Option<PageNode>>,
    free: Vec<usize>,
    /// Per-family first-page children (the radix roots).
    roots: BTreeMap<FamilyKey, Vec<usize>>,
    /// Bytes of every resident page (pinned + cached).
    resident_bytes: usize,
    /// Bytes of pages with refcount > 0 (charged, unevictable).
    pinned_bytes: usize,
    clock: u64,
}

/// A pinned page chain for one request. Holders must call
/// [`PrefixCache::release`] exactly once when the batch retires.
#[derive(Debug, Clone)]
pub struct PrefixClaim {
    pub family: FamilyKey,
    /// Node ids, first page → last.
    pub chain: Vec<usize>,
    /// Total K/V rows covered by the chain.
    pub rows: usize,
    pub page_rows: usize,
    /// Bytes newly charged by this intern (pages nobody else had).
    pub new_bytes: usize,
    /// Bytes served from already-resident shared pages.
    pub shared_bytes: usize,
}

pub struct PrefixCache {
    capacity_bytes: usize,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    new_bytes_total: AtomicU64,
    shared_bytes_total: AtomicU64,
    evictions: AtomicU64,
    waits: AtomicU64,
    peak_bytes: AtomicU64,
}

/// Head-major row-range gather: rows `r0 .. r0+rows` of a
/// `[heads][total_rows][dim]` tensor, preserving head order.
fn gather_rows(
    src: &[f32],
    heads: usize,
    total_rows: usize,
    dim: usize,
    r0: usize,
    rows: usize,
) -> Vec<f32> {
    let mut out = Vec::with_capacity(heads * rows * dim);
    for h in 0..heads {
        let base = h * total_rows * dim + r0 * dim;
        out.extend_from_slice(&src[base..base + rows * dim]);
    }
    out
}

/// FNV-1a over the exact bit patterns (so +0.0 and -0.0 hash apart and
/// bitwise-equal pages always collide into the same bucket).
fn page_hash(k: &[f32], v: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for x in k.iter().chain(v.iter()) {
        h = (h ^ u64::from(x.to_bits())).wrapping_mul(0x1_0000_0001_b3);
    }
    h
}

impl PrefixCache {
    pub fn new(capacity_bytes: usize) -> Self {
        PrefixCache {
            capacity_bytes,
            inner: Mutex::new(Inner {
                nodes: Vec::new(),
                free: Vec::new(),
                roots: BTreeMap::new(),
                resident_bytes: 0,
                pinned_bytes: 0,
                clock: 0,
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            new_bytes_total: AtomicU64::new(0),
            shared_bytes_total: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            waits: AtomicU64::new(0),
            peak_bytes: AtomicU64::new(0),
        }
    }

    /// Rows per page for a family: the paged layout's page size, or the
    /// whole cache as one run for dense layouts (degenerate but still
    /// shareable between identical caches).
    pub fn page_rows(fam: &FamilyKey) -> usize {
        match fam.kv_layout {
            crate::sketch::spec::KvLayout::Paged { page_size } => page_size.max(1),
            _ => fam.kv.max(1),
        }
    }

    /// Evict the least-recently-released unpinned leaf. Returns false
    /// when nothing is evictable (everything pinned or an interior of a
    /// cached chain).
    fn evict_one(g: &mut Inner) -> bool {
        let mut best: Option<(usize, u64)> = None;
        for (id, slot) in g.nodes.iter().enumerate() {
            if let Some(n) = slot {
                if n.refcount == 0
                    && n.children.is_empty()
                    && best.map_or(true, |(_, t)| n.last_release < t)
                {
                    best = Some((id, n.last_release));
                }
            }
        }
        let Some((id, _)) = best else { return false };
        let node = g.nodes[id].take().expect("evict target alive");
        g.resident_bytes -= node.bytes;
        match node.parent {
            Some(p) => {
                if let Some(pn) = g.nodes[p].as_mut() {
                    pn.children.retain(|&c| c != id);
                }
            }
            None => {
                if let Some(kids) = g.roots.get_mut(&node.family) {
                    kids.retain(|&c| c != id);
                }
            }
        }
        g.free.push(id);
        true
    }

    /// Make room for `bytes` more resident bytes, evicting LRU runs.
    /// When nothing is evictable, admits only if every pinned byte
    /// belongs to the caller's own in-progress claim (`own_pinned`) —
    /// the idle-admit progress guarantee.
    fn make_room(&self, g: &mut Inner, bytes: usize, own_pinned: usize) -> bool {
        loop {
            if g.resident_bytes.saturating_add(bytes) <= self.capacity_bytes {
                return true;
            }
            if Self::evict_one(g) {
                self.evictions.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            return g.pinned_bytes <= own_pinned;
        }
    }

    fn unpin(g: &mut Inner, chain: &[usize]) {
        g.clock += 1;
        let stamp = g.clock;
        for &id in chain {
            if let Some(n) = g.nodes[id].as_mut() {
                n.refcount = n.refcount.saturating_sub(1);
                if n.refcount == 0 {
                    g.pinned_bytes = g.pinned_bytes.saturating_sub(n.bytes);
                    n.last_release = stamp;
                }
            }
        }
    }

    fn alloc_node(g: &mut Inner, node: PageNode) -> usize {
        match g.free.pop() {
            Some(id) => {
                g.nodes[id] = Some(node);
                id
            }
            None => {
                g.nodes.push(Some(node));
                g.nodes.len() - 1
            }
        }
    }

    /// Intern one request's K/V (`[kv_heads][kv][dim]` head-major, the
    /// serving payload layout) into the radix tree, pinning the chain.
    /// Returns `None` when the byte budget defers admission — the
    /// caller leaves the request queued and retries next tick.
    pub fn intern(&self, fam: &FamilyKey, k: &[f32], v: &[f32]) -> Option<PrefixClaim> {
        let pr = Self::page_rows(fam);
        let (kh, d, vd, kvl) = (fam.kv_heads, fam.qk_dim, fam.v_dim, fam.kv);
        debug_assert_eq!(k.len(), fam.k_len(), "intern K payload size");
        debug_assert_eq!(v.len(), fam.v_len(), "intern V payload size");
        let n_pages = kvl.div_ceil(pr).max(1);
        let mut g = lock(&self.inner);
        g.clock += 1;
        let mut chain: Vec<usize> = Vec::with_capacity(n_pages);
        let mut new_bytes = 0usize;
        let mut shared_bytes = 0usize;
        let mut parent: Option<usize> = None;
        for p in 0..n_pages {
            let r0 = p * pr;
            let rows = ((p + 1) * pr).min(kvl) - r0;
            let kp = gather_rows(k, kh, kvl, d, r0, rows);
            let vp = gather_rows(v, kh, kvl, vd, r0, rows);
            let h = page_hash(&kp, &vp);
            let kids: Vec<usize> = match parent {
                None => g.roots.get(fam).cloned().unwrap_or_default(),
                Some(c) => {
                    g.nodes[c].as_ref().map(|n| n.children.clone()).unwrap_or_default()
                }
            };
            // Hash narrows the candidates; bitwise equality decides.
            let hit = kids.iter().copied().find(|&id| {
                g.nodes[id]
                    .as_ref()
                    .is_some_and(|n| n.rows == rows && n.hash == h && n.k == kp && n.v == vp)
            });
            match hit {
                Some(id) => {
                    let n = g.nodes[id].as_mut().expect("hit node alive");
                    if n.refcount == 0 {
                        g.pinned_bytes += n.bytes;
                    }
                    n.refcount += 1;
                    shared_bytes += n.bytes;
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    chain.push(id);
                    parent = Some(id);
                }
                None => {
                    let bytes = (kp.len() + vp.len()) * std::mem::size_of::<f32>();
                    if !self.make_room(&mut g, bytes, shared_bytes + new_bytes) {
                        Self::unpin(&mut g, &chain);
                        self.waits.fetch_add(1, Ordering::Relaxed);
                        return None;
                    }
                    let id = Self::alloc_node(
                        &mut g,
                        PageNode {
                            family: fam.clone(),
                            parent,
                            children: Vec::new(),
                            refcount: 1,
                            last_release: 0,
                            rows,
                            hash: h,
                            k: kp,
                            v: vp,
                            bytes,
                        },
                    );
                    match parent {
                        Some(c) => g.nodes[c].as_mut().expect("parent alive").children.push(id),
                        None => g.roots.entry(fam.clone()).or_default().push(id),
                    }
                    g.resident_bytes += bytes;
                    g.pinned_bytes += bytes;
                    new_bytes += bytes;
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    chain.push(id);
                    parent = Some(id);
                }
            }
        }
        self.peak_bytes.fetch_max(g.resident_bytes as u64, Ordering::Relaxed);
        self.new_bytes_total.fetch_add(new_bytes as u64, Ordering::Relaxed);
        self.shared_bytes_total.fetch_add(shared_bytes as u64, Ordering::Relaxed);
        Some(PrefixClaim {
            family: fam.clone(),
            chain,
            rows: kvl,
            page_rows: pr,
            new_bytes,
            shared_bytes,
        })
    }

    /// Unpin a claim's chain. The pages stay resident (LRU-evictable
    /// once refcount-0) so the next request with the same prefix hits.
    pub fn release(&self, claim: &PrefixClaim) {
        let mut g = lock(&self.inner);
        Self::unpin(&mut g, &claim.chain);
    }

    /// Append `rows` K/V rows (`[kv_heads][rows][dim]` head-major) to a
    /// claimed sequence — the multi-step-decode growth path. A tail
    /// page shared with other claims or cached descendants is first
    /// copied into a private page (**copy-on-write**), so every other
    /// holder of the old chain keeps reading the original bytes.
    /// Returns `None` when the byte budget defers the extension.
    pub fn cow_extend(
        &self,
        claim: &mut PrefixClaim,
        k_rows: &[f32],
        v_rows: &[f32],
        rows: usize,
    ) -> Option<()> {
        let f = claim.family.clone();
        let (kh, d, vd, pr) = (f.kv_heads, f.qk_dim, f.v_dim, claim.page_rows);
        debug_assert_eq!(k_rows.len(), kh * rows * d);
        debug_assert_eq!(v_rows.len(), kh * rows * vd);
        let mut g = lock(&self.inner);
        g.clock += 1;

        // Budget upfront: worst case is one COW copy of the tail plus
        // all the appended rows.
        let row_bytes = (d + vd) * kh * std::mem::size_of::<f32>();
        let tail_copy_bytes = claim
            .chain
            .last()
            .and_then(|&id| g.nodes[id].as_ref())
            .map_or(0, |n| n.bytes);
        let own_pinned = claim.shared_bytes + claim.new_bytes;
        if !self.make_room(&mut g, tail_copy_bytes + rows * row_bytes, own_pinned) {
            self.waits.fetch_add(1, Ordering::Relaxed);
            return None;
        }

        let mut appended = 0usize;
        while appended < rows {
            let tail = claim.chain.last().copied();
            let (tail_rows, tail_shared) = match tail.and_then(|id| g.nodes[id].as_ref()) {
                Some(n) => (n.rows, n.refcount > 1 || !n.children.is_empty()),
                None => (pr, false), // no tail: open a fresh page below
            };
            if tail_rows < pr {
                let id = tail.expect("partial tail exists");
                let take = (pr - tail_rows).min(rows - appended);
                if tail_shared {
                    // COW: private copy of the tail, siblinged next to
                    // the shared original, which loses this claim's pin.
                    let (pk, pv, pb, prows, pparent) = {
                        let n = g.nodes[id].as_ref().expect("tail alive");
                        (n.k.clone(), n.v.clone(), n.bytes, n.rows, n.parent)
                    };
                    let copy = Self::alloc_node(
                        &mut g,
                        PageNode {
                            family: f.clone(),
                            parent: pparent,
                            children: Vec::new(),
                            refcount: 1,
                            last_release: 0,
                            rows: prows,
                            hash: 0, // recomputed after the append below
                            k: pk,
                            v: pv,
                            bytes: pb,
                        },
                    );
                    match pparent {
                        Some(c) => {
                            g.nodes[c].as_mut().expect("parent alive").children.push(copy)
                        }
                        None => g.roots.entry(f.clone()).or_default().push(copy),
                    }
                    g.resident_bytes += pb;
                    g.pinned_bytes += pb;
                    claim.new_bytes += pb;
                    self.peak_bytes.fetch_max(g.resident_bytes as u64, Ordering::Relaxed);
                    Self::unpin(&mut g, &[id]);
                    claim.shared_bytes = claim.shared_bytes.saturating_sub(pb);
                    *claim.chain.last_mut().expect("chain tail") = copy;
                    continue; // retry the append against the private copy
                }
                // Private partial tail: append in place, head-major.
                let n = g.nodes[id].as_mut().expect("tail alive");
                let (old, new) = (n.rows, n.rows + take);
                let mut k2 = Vec::with_capacity(kh * new * d);
                let mut v2 = Vec::with_capacity(kh * new * vd);
                for h in 0..kh {
                    k2.extend_from_slice(&n.k[h * old * d..(h + 1) * old * d]);
                    k2.extend_from_slice(
                        &k_rows[h * rows * d + appended * d..h * rows * d + (appended + take) * d],
                    );
                    v2.extend_from_slice(&n.v[h * old * vd..(h + 1) * old * vd]);
                    v2.extend_from_slice(
                        &v_rows
                            [h * rows * vd + appended * vd..h * rows * vd + (appended + take) * vd],
                    );
                }
                let added = take * row_bytes;
                n.rows = new;
                n.hash = page_hash(&k2, &v2);
                n.k = k2;
                n.v = v2;
                n.bytes += added;
                g.resident_bytes += added;
                g.pinned_bytes += added;
                claim.new_bytes += added;
                claim.rows += take;
                appended += take;
            } else {
                // Tail full: open a new private child page.
                let take = pr.min(rows - appended);
                let kp = gather_rows(k_rows, kh, rows, d, appended, take);
                let vp = gather_rows(v_rows, kh, rows, vd, appended, take);
                let bytes = (kp.len() + vp.len()) * std::mem::size_of::<f32>();
                let h = page_hash(&kp, &vp);
                let id = Self::alloc_node(
                    &mut g,
                    PageNode {
                        family: f.clone(),
                        parent: tail,
                        children: Vec::new(),
                        refcount: 1,
                        last_release: 0,
                        rows: take,
                        hash: h,
                        k: kp,
                        v: vp,
                        bytes,
                    },
                );
                match tail {
                    Some(c) => g.nodes[c].as_mut().expect("tail alive").children.push(id),
                    None => g.roots.entry(f.clone()).or_default().push(id),
                }
                g.resident_bytes += bytes;
                g.pinned_bytes += bytes;
                claim.new_bytes += bytes;
                claim.chain.push(id);
                claim.rows += take;
                appended += take;
            }
        }
        self.peak_bytes.fetch_max(g.resident_bytes as u64, Ordering::Relaxed);
        Some(())
    }

    /// Copy the pages `ids` into batch-local pools (head-major
    /// `[kv_heads][page_rows][dim]` per page, partial tails zero-padded
    /// to the full page height). The batch packer renumbers block
    /// tables against this pool so executors never see cache node ids.
    pub fn export_pages(&self, fam: &FamilyKey, ids: &[usize]) -> (Vec<f32>, Vec<f32>) {
        let pr = Self::page_rows(fam);
        let (kh, d, vd) = (fam.kv_heads, fam.qk_dim, fam.v_dim);
        let kp_len = kh * pr * d;
        let vp_len = kh * pr * vd;
        let mut kps = vec![0.0f32; ids.len() * kp_len];
        let mut vps = vec![0.0f32; ids.len() * vp_len];
        let g = lock(&self.inner);
        for (i, &id) in ids.iter().enumerate() {
            let Some(n) = g.nodes.get(id).and_then(|s| s.as_ref()) else { continue };
            for h in 0..kh {
                kps[i * kp_len + h * pr * d..][..n.rows * d]
                    .copy_from_slice(&n.k[h * n.rows * d..(h + 1) * n.rows * d]);
                vps[i * vp_len + h * pr * vd..][..n.rows * vd]
                    .copy_from_slice(&n.v[h * n.rows * vd..(h + 1) * n.rows * vd]);
            }
        }
        (kps, vps)
    }

    /// Reassemble a claim's dense head-major K/V (test oracle for the
    /// COW bit-identity guarantee).
    pub fn gather(&self, claim: &PrefixClaim) -> (Vec<f32>, Vec<f32>) {
        let f = &claim.family;
        let (kh, d, vd) = (f.kv_heads, f.qk_dim, f.v_dim);
        let rows = claim.rows;
        let mut k = vec![0.0f32; kh * rows * d];
        let mut v = vec![0.0f32; kh * rows * vd];
        let g = lock(&self.inner);
        let mut r0 = 0usize;
        for &id in &claim.chain {
            let n = g.nodes[id].as_ref().expect("claim node alive");
            for h in 0..kh {
                k[h * rows * d + r0 * d..][..n.rows * d]
                    .copy_from_slice(&n.k[h * n.rows * d..(h + 1) * n.rows * d]);
                v[h * rows * vd + r0 * vd..][..n.rows * vd]
                    .copy_from_slice(&n.v[h * n.rows * vd..(h + 1) * n.rows * vd]);
            }
            r0 += n.rows;
        }
        (k, v)
    }

    pub fn pinned_bytes(&self) -> usize {
        lock(&self.inner).pinned_bytes
    }

    pub fn resident_bytes(&self) -> usize {
        lock(&self.inner).resident_bytes
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn new_bytes_total(&self) -> u64 {
        self.new_bytes_total.load(Ordering::Relaxed)
    }

    pub fn shared_bytes_total(&self) -> u64 {
        self.shared_bytes_total.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    pub fn waits(&self) -> u64 {
        self.waits.load(Ordering::Relaxed)
    }

    pub fn peak_bytes(&self) -> u64 {
        self.peak_bytes.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::spec::{AttnVariant, Direction, KvLayout, ScorePattern};

    fn fam(kv: usize, page: usize) -> FamilyKey {
        FamilyKey {
            variant: AttnVariant::Gqa,
            causal: false,
            qk_dim: 8,
            v_dim: 8,
            q_heads: 4,
            kv_heads: 2,
            seq: 1,
            kv,
            kv_layout: KvLayout::Paged { page_size: page },
            direction: Direction::Forward,
            pattern: ScorePattern::Dense,
        }
    }

    fn payload(f: &FamilyKey, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let gen = |n: usize, salt: u64| -> Vec<f32> {
            (0..n)
                .map(|i| {
                    let x = (i as u64).wrapping_add(seed.wrapping_mul(31).wrapping_add(salt));
                    (x.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 40) as f32 / 1e4
                })
                .collect()
        };
        (gen(f.k_len(), 1), gen(f.v_len(), 2))
    }

    #[test]
    fn identical_chains_share_every_page() {
        let f = fam(32, 8);
        let cache = PrefixCache::new(usize::MAX);
        let (k, v) = payload(&f, 7);
        let a = cache.intern(&f, &k, &v).unwrap();
        assert_eq!(a.shared_bytes, 0);
        assert!(a.new_bytes > 0);
        let b = cache.intern(&f, &k, &v).unwrap();
        assert_eq!(b.new_bytes, 0, "fanout twin charges nothing");
        assert_eq!(b.shared_bytes, a.new_bytes);
        assert_eq!(b.chain, a.chain, "same physical pages");
        assert_eq!(cache.resident_bytes(), a.new_bytes, "shared pages charged once");
        cache.release(&a);
        cache.release(&b);
        assert_eq!(cache.pinned_bytes(), 0, "drain unpins everything");
        assert_eq!(cache.resident_bytes(), a.new_bytes, "pages stay cached");
    }

    #[test]
    fn divergent_suffix_shares_only_the_prefix() {
        let f = fam(32, 8); // 4 pages
        let cache = PrefixCache::new(usize::MAX);
        let (k, v) = payload(&f, 7);
        let (mut k2, v2) = (k.clone(), v.clone());
        // Flip one element in the last page's rows of head 0.
        k2[31 * f.qk_dim] += 1.0;
        let a = cache.intern(&f, &k, &v).unwrap();
        let b = cache.intern(&f, &k2, &v2).unwrap();
        assert_eq!(b.chain[..3], a.chain[..3], "first three pages shared");
        assert_ne!(b.chain[3], a.chain[3], "diverged tail gets its own page");
        assert!(b.shared_bytes > 0 && b.new_bytes > 0);
        cache.release(&a);
        cache.release(&b);
        assert_eq!(cache.pinned_bytes(), 0);
    }

    #[test]
    fn budget_defers_then_admits_and_evicts_lru() {
        let f = fam(16, 16); // one page per chain
        let (ka, va) = payload(&f, 1);
        let (kb, vb) = payload(&f, 2);
        let (kc, vc) = payload(&f, 3);
        let chain_bytes = (f.k_len() + f.v_len()) * 4;
        let cache = PrefixCache::new(2 * chain_bytes);
        let a = cache.intern(&f, &ka, &va).unwrap();
        let b = cache.intern(&f, &kb, &vb).unwrap();
        // Both pinned, budget full: a third distinct chain defers.
        assert!(cache.intern(&f, &kc, &vc).is_none());
        assert!(cache.waits() > 0);
        cache.release(&a);
        // A is now LRU refcount-0: C evicts it and admits.
        let c = cache.intern(&f, &kc, &vc).unwrap();
        assert!(cache.evictions() > 0);
        assert!(cache.resident_bytes() <= 2 * chain_bytes);
        cache.release(&b);
        cache.release(&c);
        assert_eq!(cache.pinned_bytes(), 0);
    }

    #[test]
    fn oversized_sequence_admitted_when_idle() {
        let f = fam(64, 16);
        let cache = PrefixCache::new(8); // comically small budget
        let (k, v) = payload(&f, 9);
        // Idle-admit progress guarantee: the only claimant always gets in.
        let a = cache.intern(&f, &k, &v).expect("idle cache admits oversized chain");
        // A second concurrent distinct chain must defer.
        let (k2, v2) = payload(&f, 10);
        assert!(cache.intern(&f, &k2, &v2).is_none());
        cache.release(&a);
        assert_eq!(cache.pinned_bytes(), 0);
    }

    #[test]
    fn gather_roundtrips_and_export_pads_partial_pages() {
        let f = fam(24, 16); // pages of 16 + partial 8
        let cache = PrefixCache::new(usize::MAX);
        let (k, v) = payload(&f, 4);
        let a = cache.intern(&f, &k, &v).unwrap();
        let (gk, gv) = cache.gather(&a);
        assert_eq!(gk, k, "gather is bitwise");
        assert_eq!(gv, v);
        let (kp, vp) = cache.export_pages(&f, &a.chain);
        let pr = PrefixCache::page_rows(&f);
        assert_eq!(kp.len(), a.chain.len() * f.kv_heads * pr * f.qk_dim);
        // Padding rows of the partial tail are zero.
        let tail = &kp[(a.chain.len() - 1) * f.kv_heads * pr * f.qk_dim..];
        let pad = &tail[8 * f.qk_dim..pr * f.qk_dim]; // head 0 rows 8..16
        assert!(pad.iter().all(|x| *x == 0.0));
        assert_eq!(vp.len(), a.chain.len() * f.kv_heads * pr * f.v_dim);
        cache.release(&a);
    }

    #[test]
    fn cow_extend_copies_shared_tail_before_writing() {
        let f = fam(24, 16); // partial tail page of 8 rows
        let cache = PrefixCache::new(usize::MAX);
        let (k, v) = payload(&f, 4);
        let a = cache.intern(&f, &k, &v).unwrap();
        let mut b = cache.intern(&f, &k, &v).unwrap();
        assert_eq!(b.chain, a.chain);
        let (ak0, av0) = cache.gather(&a);
        // Extend B by 4 rows: the shared partial tail must be COW-copied.
        let (kh, d, vd) = (f.kv_heads, f.qk_dim, f.v_dim);
        let krows: Vec<f32> = (0..kh * 4 * d).map(|i| 100.0 + i as f32).collect();
        let vrows: Vec<f32> = (0..kh * 4 * vd).map(|i| 200.0 + i as f32).collect();
        cache.cow_extend(&mut b, &krows, &vrows, 4).unwrap();
        assert_eq!(b.rows, 28);
        assert_ne!(b.chain.last(), a.chain.last(), "tail privatized");
        // A's view is bit-identical to before the write.
        let (ak1, av1) = cache.gather(&a);
        assert_eq!(ak1, ak0, "COW: shared readers never observe the mutation");
        assert_eq!(av1, av0);
        // B's view is the original plus the appended rows, head-major.
        let (bk, _bv) = cache.gather(&b);
        for h in 0..kh {
            assert_eq!(&bk[h * 28 * d..h * 28 * d + 24 * d], &k[h * 24 * d..(h + 1) * 24 * d]);
            assert_eq!(&bk[h * 28 * d + 24 * d..(h + 1) * 28 * d], &krows[h * 4 * d..(h + 1) * 4 * d]);
        }
        cache.release(&a);
        cache.release(&b);
        assert_eq!(cache.pinned_bytes(), 0, "refcounts balance after COW");
    }

    #[test]
    fn cow_extend_past_page_boundary_opens_children() {
        let f = fam(16, 16); // full single page
        let cache = PrefixCache::new(usize::MAX);
        let (k, v) = payload(&f, 4);
        let mut a = cache.intern(&f, &k, &v).unwrap();
        let (kh, d, vd) = (f.kv_heads, f.qk_dim, f.v_dim);
        let krows: Vec<f32> = (0..kh * 20 * d).map(|i| i as f32).collect();
        let vrows: Vec<f32> = (0..kh * 20 * vd).map(|i| -(i as f32)).collect();
        cache.cow_extend(&mut a, &krows, &vrows, 20).unwrap();
        assert_eq!(a.rows, 36);
        assert_eq!(a.chain.len(), 3, "16 + 16 + partial 4");
        let (gk, _) = cache.gather(&a);
        for h in 0..kh {
            assert_eq!(&gk[h * 36 * d..h * 36 * d + 16 * d], &k[h * 16 * d..(h + 1) * 16 * d]);
            assert_eq!(&gk[h * 36 * d + 16 * d..(h + 1) * 36 * d], &krows[h * 20 * d..(h + 1) * 20 * d]);
        }
        cache.release(&a);
        assert_eq!(cache.pinned_bytes(), 0);
    }
}
