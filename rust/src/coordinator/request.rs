//! Request/response types for the attention-serving coordinator.

use std::sync::mpsc;
use std::time::Instant;

use crate::sketch::spec::{AttnVariant, Direction, KvLayout};

/// The routing key: everything that identifies a kernel family + problem
/// shape except the batch dimension (which the batcher chooses). The KV
/// layout is part of the family — a paged kernel takes a block-table
/// operand, so paged and contiguous traffic can never share a batch —
/// and so is the pass direction (a backward kernel consumes dO/lse/delta
/// and produces gradients).
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FamilyKey {
    pub variant: AttnVariant,
    pub causal: bool,
    pub qk_dim: usize,
    pub v_dim: usize,
    pub q_heads: usize,
    pub kv_heads: usize,
    pub seq: usize,
    pub kv: usize,
    pub kv_layout: KvLayout,
    pub direction: Direction,
}

/// Ingress lane: decode-shaped traffic (short query against a long KV
/// cache — the autoregressive inner loop) is batched and routed apart
/// from prefill so it can pack into split-K artifact variants with
/// KV-cache-aware capacities. The lane is a pure function of the family
/// shape, so batcher and router agree without extra request state.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum LaneKey {
    Prefill,
    Decode,
}

impl LaneKey {
    /// Decode-shaped: a handful of query rows attending over a KV cache
    /// at least 4x longer. Everything else is prefill.
    pub fn of(f: &FamilyKey) -> LaneKey {
        if f.seq <= 16 && f.kv >= 4 * f.seq {
            LaneKey::Decode
        } else {
            LaneKey::Prefill
        }
    }
}

impl std::fmt::Display for LaneKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            LaneKey::Prefill => "prefill",
            LaneKey::Decode => "decode",
        })
    }
}

impl FamilyKey {
    /// Element counts per single request.
    pub fn q_len(&self) -> usize {
        self.q_heads * self.seq * self.qk_dim
    }

    pub fn k_len(&self) -> usize {
        self.kv_heads * self.kv * self.qk_dim
    }

    pub fn v_len(&self) -> usize {
        self.kv_heads * self.kv * self.v_dim
    }

    pub fn out_len(&self) -> usize {
        self.q_heads * self.seq * self.v_dim
    }

    /// Host bytes of K+V one batch slot pins (f32), **by layout**: the
    /// decode lane clamps its batch capacities so `capacity * kv_bytes`
    /// stays inside the configured KV-cache budget, counting pages
    /// actually resident instead of worst-case contiguous bytes.
    ///
    /// * Contiguous: the full dense cache.
    /// * Paged: `ceil(kv / page) pages` of K and V, plus the block table
    ///   (8 bytes per page) — dense rounded up to page granularity.
    /// * Sliding: only the trailing `window` rows stay resident; older
    ///   pages are recycled by the pool.
    pub fn kv_bytes(&self) -> usize {
        let row = (self.qk_dim + self.v_dim) * self.kv_heads * std::mem::size_of::<f32>();
        match self.kv_layout {
            KvLayout::Contiguous => self.kv * row,
            KvLayout::Paged { page_size } => {
                let page = page_size.max(1);
                let pages = self.kv.div_ceil(page);
                pages * page * row + pages * std::mem::size_of::<i64>()
            }
            KvLayout::Sliding { window } => self.kv.min(window) * row,
        }
    }

}

/// One attention request: per-request Q/K/V (batch dim 1).
pub struct AttnRequest {
    pub id: u64,
    pub family: FamilyKey,
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<AttnResponse>,
}

#[derive(Debug)]
pub struct AttnResponse {
    pub id: u64,
    pub result: Result<Vec<f32>, String>,
    /// Queueing + execution time.
    pub latency: std::time::Duration,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_lengths() {
        let f = FamilyKey {
            variant: AttnVariant::Gqa,
            causal: true,
            qk_dim: 64,
            v_dim: 64,
            q_heads: 8,
            kv_heads: 2,
            seq: 256,
            kv: 256,
            kv_layout: KvLayout::Contiguous,
            direction: Direction::Forward,
        };
        assert_eq!(f.q_len(), 8 * 256 * 64);
        assert_eq!(f.k_len(), 2 * 256 * 64);
        assert_eq!(f.out_len(), 8 * 256 * 64);
        assert_eq!(f.kv_bytes(), 2 * (2 * 256 * 64) * 4);
    }

    #[test]
    fn kv_bytes_counts_resident_pages_not_worst_case() {
        let dense = FamilyKey {
            variant: AttnVariant::Mha,
            causal: false,
            qk_dim: 64,
            v_dim: 64,
            q_heads: 4,
            kv_heads: 4,
            seq: 1,
            kv: 1000, // deliberately not page-aligned
            kv_layout: KvLayout::Contiguous,
            direction: Direction::Forward,
        };
        let row = (64 + 64) * 4 * 4;
        assert_eq!(dense.kv_bytes(), 1000 * row);
        let paged = FamilyKey {
            kv_layout: KvLayout::Paged { page_size: 16 },
            direction: Direction::Forward,
            ..dense.clone()
        };
        // 63 pages of 16 rows + 8-byte table entries.
        assert_eq!(paged.kv_bytes(), 63 * 16 * row + 63 * 8);
        let sliding = FamilyKey {
            kv_layout: KvLayout::Sliding { window: 128 },
            direction: Direction::Forward,
            ..dense.clone()
        };
        assert_eq!(sliding.kv_bytes(), 128 * row, "only the window stays resident");
    }

    #[test]
    fn lane_classification() {
        let mut f = FamilyKey {
            variant: AttnVariant::Mha,
            causal: true,
            qk_dim: 64,
            v_dim: 64,
            q_heads: 4,
            kv_heads: 4,
            seq: 256,
            kv: 256,
            kv_layout: KvLayout::Contiguous,
            direction: Direction::Forward,
        };
        assert_eq!(LaneKey::of(&f), LaneKey::Prefill);
        // One query row over a long cache: decode.
        f.seq = 1;
        f.kv = 1024;
        assert_eq!(LaneKey::of(&f), LaneKey::Decode);
        // Short query but short cache too: still prefill.
        f.seq = 16;
        f.kv = 16;
        assert_eq!(LaneKey::of(&f), LaneKey::Prefill);
        // Boundary: seq 16 against >= 64 cache rows is decode.
        f.kv = 64;
        assert_eq!(LaneKey::of(&f), LaneKey::Decode);
    }
}
