//! Request/response types for the attention-serving coordinator.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::sketch::spec::{AttnVariant, Direction, KvLayout, ScorePattern};

/// The routing key: everything that identifies a kernel family + problem
/// shape except the batch dimension (which the batcher chooses). The KV
/// layout is part of the family — a paged kernel takes a block-table
/// operand, so paged and contiguous traffic can never share a batch —
/// and so is the pass direction (a backward kernel consumes dO/lse/delta
/// and produces gradients) and the score pattern (a block-sparse kernel
/// takes a selection-table operand; window+global bakes its mask
/// constants into the artifact).
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FamilyKey {
    pub variant: AttnVariant,
    pub causal: bool,
    pub qk_dim: usize,
    pub v_dim: usize,
    pub q_heads: usize,
    pub kv_heads: usize,
    pub seq: usize,
    pub kv: usize,
    pub kv_layout: KvLayout,
    pub direction: Direction,
    pub pattern: ScorePattern,
}

/// Ingress lane: decode-shaped traffic (short query against a long KV
/// cache — the autoregressive inner loop) is batched and routed apart
/// from prefill so it can pack into split-K artifact variants with
/// KV-cache-aware capacities. The lane is a pure function of the family
/// shape, so batcher and router agree without extra request state.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum LaneKey {
    Prefill,
    Decode,
}

impl LaneKey {
    /// Decode-shaped: a handful of query rows attending over a KV cache
    /// at least 4x longer. Everything else is prefill.
    pub fn of(f: &FamilyKey) -> LaneKey {
        if f.seq <= 16 && f.kv >= 4 * f.seq {
            LaneKey::Decode
        } else {
            LaneKey::Prefill
        }
    }
}

impl std::fmt::Display for LaneKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            LaneKey::Prefill => "prefill",
            LaneKey::Decode => "decode",
        })
    }
}

impl FamilyKey {
    /// Element counts per single request.
    pub fn q_len(&self) -> usize {
        self.q_heads * self.seq * self.qk_dim
    }

    pub fn k_len(&self) -> usize {
        self.kv_heads * self.kv * self.qk_dim
    }

    pub fn v_len(&self) -> usize {
        self.kv_heads * self.kv * self.v_dim
    }

    pub fn out_len(&self) -> usize {
        self.q_heads * self.seq * self.v_dim
    }

    /// Host bytes of K+V one batch slot pins (f32), **by layout**: the
    /// decode lane clamps its batch capacities so `capacity * kv_bytes`
    /// stays inside the configured KV-cache budget, counting pages
    /// actually resident instead of worst-case contiguous bytes.
    ///
    /// * Contiguous: the full dense cache.
    /// * Paged: `ceil(kv / page) pages` of K and V, plus the block table
    ///   (8 bytes per page) — dense rounded up to page granularity.
    /// * Sliding: only the trailing `window` rows stay resident; older
    ///   pages are recycled by the pool.
    ///
    /// Sparse score patterns then clip the residency to their attended
    /// rows: block-sparse pins `topk * block` selected rows plus the
    /// 8-byte selection-table entries; window+global pins the trailing
    /// window and the leading globals. Dense is charged unchanged.
    pub fn kv_bytes(&self) -> usize {
        let row = (self.qk_dim + self.v_dim) * self.kv_heads * std::mem::size_of::<f32>();
        let base = match self.kv_layout {
            KvLayout::Contiguous => self.kv * row,
            KvLayout::Paged { page_size } => {
                let page = page_size.max(1);
                let pages = self.kv.div_ceil(page);
                pages * page * row + pages * std::mem::size_of::<i64>()
            }
            KvLayout::Sliding { window } => self.kv.min(window) * row,
        };
        match self.pattern {
            ScorePattern::Dense => base,
            ScorePattern::BlockSparse { block, topk } => base
                .min(self.kv.min(topk * block) * row + topk * std::mem::size_of::<i64>()),
            ScorePattern::WindowGlobal { window, n_global } => {
                base.min(self.kv.min(window + n_global) * row)
            }
        }
    }

}

/// One attention request: per-request Q/K/V (batch dim 1).
pub struct AttnRequest {
    pub id: u64,
    pub family: FamilyKey,
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub enqueued: Instant,
    /// Absolute deadline; past it the request is shed with a
    /// [`RequestOutcome::Timeout`] instead of being executed.
    pub deadline: Option<Instant>,
    /// Executions attempted so far (bumped when a shard claims the
    /// request into a batch, so crash loops are bounded even when the
    /// executor panics mid-batch).
    pub attempts: u32,
    /// Retry backoff: the request is not planned into a batch before
    /// this instant (set when a failed execution requeues it).
    pub not_before: Option<Instant>,
    /// Exactly-once reply slot, shared with the supervisor so a request
    /// recovered off a hung shard can never be answered twice.
    pub reply: Arc<ReplySlot>,
}

/// Terminal outcome of one request. Every submitted request receives
/// exactly one of these — success, deadline expiry, or a failure after
/// the retry budget is exhausted. There is no silent-drop path.
#[derive(Debug, Clone, PartialEq)]
pub enum RequestOutcome {
    /// The flattened output tensor (`family.out_len()` elements).
    Ok(Vec<f32>),
    /// The deadline passed while the request was queued or in flight.
    Timeout,
    /// Executor / routing failure after retries were exhausted.
    Failed(String),
}

impl RequestOutcome {
    pub fn is_ok(&self) -> bool {
        matches!(self, RequestOutcome::Ok(_))
    }

    /// Borrow the output when the request succeeded.
    pub fn ok(&self) -> Option<&Vec<f32>> {
        match self {
            RequestOutcome::Ok(out) => Some(out),
            _ => None,
        }
    }

    /// Collapse into the pre-fault-tolerance `Result` shape (timeouts
    /// become an error string) for callers that only care about
    /// success/failure.
    pub fn into_result(self) -> Result<Vec<f32>, String> {
        match self {
            RequestOutcome::Ok(out) => Ok(out),
            RequestOutcome::Timeout => Err("deadline exceeded".to_string()),
            RequestOutcome::Failed(e) => Err(e),
        }
    }
}

#[derive(Debug)]
pub struct AttnResponse {
    pub id: u64,
    /// Terminal outcome (exactly one per request).
    pub outcome: RequestOutcome,
    /// Queueing + execution time.
    pub latency: std::time::Duration,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
    /// Executions this request consumed (1 = served first try).
    pub attempts: u32,
    /// Served by the degraded lane (bit-exact `ReferenceExecutor`
    /// fallback after every compiled variant was quarantined).
    pub degraded: bool,
}

/// Exactly-once reply channel: the first `send` wins, every later one is
/// a no-op. Shared (`Arc`) between the owning shard and the supervisor,
/// because a request recovered off a hung shard may race the original
/// thread waking up and executing its stale batch anyway.
#[derive(Debug)]
pub struct ReplySlot {
    tx: mpsc::Sender<AttnResponse>,
    sent: AtomicBool,
}

impl ReplySlot {
    pub fn new(tx: mpsc::Sender<AttnResponse>) -> Self {
        ReplySlot { tx, sent: AtomicBool::new(false) }
    }

    /// Deliver the terminal response; returns `false` if one was already
    /// delivered (the duplicate is dropped).
    pub fn send(&self, resp: AttnResponse) -> bool {
        if self.sent.swap(true, Ordering::AcqRel) {
            return false;
        }
        let _ = self.tx.send(resp);
        true
    }

    /// Has a terminal response already been delivered?
    pub fn is_sent(&self) -> bool {
        self.sent.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_lengths() {
        let f = FamilyKey {
            variant: AttnVariant::Gqa,
            causal: true,
            qk_dim: 64,
            v_dim: 64,
            q_heads: 8,
            kv_heads: 2,
            seq: 256,
            kv: 256,
            kv_layout: KvLayout::Contiguous,
            direction: Direction::Forward,
            pattern: ScorePattern::Dense,
        };
        assert_eq!(f.q_len(), 8 * 256 * 64);
        assert_eq!(f.k_len(), 2 * 256 * 64);
        assert_eq!(f.out_len(), 8 * 256 * 64);
        assert_eq!(f.kv_bytes(), 2 * (2 * 256 * 64) * 4);
    }

    #[test]
    fn kv_bytes_counts_resident_pages_not_worst_case() {
        let dense = FamilyKey {
            variant: AttnVariant::Mha,
            causal: false,
            qk_dim: 64,
            v_dim: 64,
            q_heads: 4,
            kv_heads: 4,
            seq: 1,
            kv: 1000, // deliberately not page-aligned
            kv_layout: KvLayout::Contiguous,
            direction: Direction::Forward,
            pattern: ScorePattern::Dense,
        };
        let row = (64 + 64) * 4 * 4;
        assert_eq!(dense.kv_bytes(), 1000 * row);
        let paged = FamilyKey {
            kv_layout: KvLayout::Paged { page_size: 16 },
            direction: Direction::Forward,
            ..dense.clone()
        };
        // 63 pages of 16 rows + 8-byte table entries.
        assert_eq!(paged.kv_bytes(), 63 * 16 * row + 63 * 8);
        let sliding = FamilyKey {
            kv_layout: KvLayout::Sliding { window: 128 },
            direction: Direction::Forward,
            ..dense.clone()
        };
        assert_eq!(sliding.kv_bytes(), 128 * row, "only the window stays resident");
    }

    #[test]
    fn kv_bytes_charges_sparse_patterns_by_attended_rows() {
        let dense = FamilyKey {
            variant: AttnVariant::Mha,
            causal: false,
            qk_dim: 64,
            v_dim: 64,
            q_heads: 4,
            kv_heads: 4,
            seq: 256,
            kv: 4096,
            kv_layout: KvLayout::Contiguous,
            direction: Direction::Forward,
            pattern: ScorePattern::Dense,
        };
        let row = (64 + 64) * 4 * 4;
        assert_eq!(dense.kv_bytes(), 4096 * row);
        // 16 selected tiles of 64 rows + the 8-byte selection table.
        let bs = FamilyKey {
            pattern: ScorePattern::BlockSparse { block: 64, topk: 16 },
            ..dense.clone()
        };
        assert_eq!(bs.kv_bytes(), 1024 * row + 16 * 8);
        // Trailing window + leading globals stay pinned.
        let wg = FamilyKey {
            causal: true,
            pattern: ScorePattern::WindowGlobal { window: 512, n_global: 64 },
            ..dense.clone()
        };
        assert_eq!(wg.kv_bytes(), 576 * row);
        // A selection wider than the cache can't charge more than dense.
        let wide = FamilyKey {
            pattern: ScorePattern::BlockSparse { block: 64, topk: 4096 },
            ..dense.clone()
        };
        assert!(wide.kv_bytes() <= dense.kv_bytes());
    }

    #[test]
    fn lane_classification() {
        let mut f = FamilyKey {
            variant: AttnVariant::Mha,
            causal: true,
            qk_dim: 64,
            v_dim: 64,
            q_heads: 4,
            kv_heads: 4,
            seq: 256,
            kv: 256,
            kv_layout: KvLayout::Contiguous,
            direction: Direction::Forward,
            pattern: ScorePattern::Dense,
        };
        assert_eq!(LaneKey::of(&f), LaneKey::Prefill);
        // One query row over a long cache: decode.
        f.seq = 1;
        f.kv = 1024;
        assert_eq!(LaneKey::of(&f), LaneKey::Decode);
        // Short query but short cache too: still prefill.
        f.seq = 16;
        f.kv = 16;
        assert_eq!(LaneKey::of(&f), LaneKey::Prefill);
        // Boundary: seq 16 against >= 64 cache rows is decode.
        f.kv = 64;
        assert_eq!(LaneKey::of(&f), LaneKey::Decode);
    }

    #[test]
    fn reply_slot_delivers_exactly_once() {
        let (tx, rx) = mpsc::channel();
        let slot = ReplySlot::new(tx);
        assert!(!slot.is_sent());
        let resp = |o: RequestOutcome| AttnResponse {
            id: 1,
            outcome: o,
            latency: std::time::Duration::ZERO,
            batch_size: 1,
            attempts: 1,
            degraded: false,
        };
        assert!(slot.send(resp(RequestOutcome::Ok(vec![1.0]))));
        assert!(slot.is_sent());
        // The duplicate (a hung shard waking up after recovery) is dropped.
        assert!(!slot.send(resp(RequestOutcome::Failed("late".into()))));
        let got = rx.recv().unwrap();
        assert_eq!(got.outcome, RequestOutcome::Ok(vec![1.0]));
        assert!(rx.try_recv().is_err(), "exactly one response per request");
    }

    #[test]
    fn outcome_collapses_to_result() {
        assert_eq!(RequestOutcome::Ok(vec![2.0]).into_result(), Ok(vec![2.0]));
        assert!(RequestOutcome::Timeout.into_result().unwrap_err().contains("deadline"));
        assert_eq!(RequestOutcome::Failed("x".into()).into_result(), Err("x".into()));
        assert!(RequestOutcome::Ok(vec![]).is_ok());
        assert!(!RequestOutcome::Timeout.is_ok());
    }
}
