//! Request/response types for the attention-serving coordinator.

use std::sync::mpsc;
use std::time::Instant;

use crate::sketch::spec::AttnVariant;

/// The routing key: everything that identifies a kernel family + problem
/// shape except the batch dimension (which the batcher chooses).
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FamilyKey {
    pub variant: AttnVariant,
    pub causal: bool,
    pub qk_dim: usize,
    pub v_dim: usize,
    pub q_heads: usize,
    pub kv_heads: usize,
    pub seq: usize,
    pub kv: usize,
}

impl FamilyKey {
    /// Element counts per single request.
    pub fn q_len(&self) -> usize {
        self.q_heads * self.seq * self.qk_dim
    }

    pub fn k_len(&self) -> usize {
        self.kv_heads * self.kv * self.qk_dim
    }

    pub fn v_len(&self) -> usize {
        self.kv_heads * self.kv * self.v_dim
    }

    pub fn out_len(&self) -> usize {
        self.q_heads * self.seq * self.v_dim
    }
}

/// One attention request: per-request Q/K/V (batch dim 1).
pub struct AttnRequest {
    pub id: u64,
    pub family: FamilyKey,
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<AttnResponse>,
}

#[derive(Debug)]
pub struct AttnResponse {
    pub id: u64,
    pub result: Result<Vec<f32>, String>,
    /// Queueing + execution time.
    pub latency: std::time::Duration,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_lengths() {
        let f = FamilyKey {
            variant: AttnVariant::Gqa,
            causal: true,
            qk_dim: 64,
            v_dim: 64,
            q_heads: 8,
            kv_heads: 2,
            seq: 256,
            kv: 256,
        };
        assert_eq!(f.q_len(), 8 * 256 * 64);
        assert_eq!(f.k_len(), 2 * 256 * 64);
        assert_eq!(f.out_len(), 8 * 256 * 64);
    }
}
