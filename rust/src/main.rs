//! `tlc` — the QiMeng-Attention pipeline CLI (leader entrypoint).
//!
//! Subcommands:
//!   generate      run the full pipeline for one operator, print/emit code
//!   generate-all  emit the standard kernel set into python/compile/kernels/generated/
//!   verify        run stage 1a+1b and the verification gate, print report
//!   ablate        single-stage ablation (Appendix B): show rejected TL
//!   tables        regenerate a paper table/figure from the perf model
//!   tune          schedule-space autotuning with a persistent cache
//!   serve         start the attention-serving coordinator (PJRT runtime)
//!   profile       trace all three layers (pipeline, engine, serving) and
//!                 export a Chrome trace + per-op breakdown

use qimeng::perfmodel::gpu::GpuArch;
use qimeng::pipeline::{self, Target};
use qimeng::reasoner::profiles::{FailureMode, LlmProfile};
use qimeng::sketch::spec::{AttnVariant, OpSpec};
use qimeng::tl::printer::print_program;
use qimeng::util::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("tlc: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: Vec<String>) -> Result<(), String> {
    let args = Args::parse(argv)?;
    match args.positional.first().map(String::as_str) {
        Some("generate") => cmd_generate(&args),
        Some("generate-all") => cmd_generate_all(&args),
        Some("verify") => cmd_verify(&args),
        Some("ablate") => cmd_ablate(&args),
        Some("tables") => cmd_tables(&args),
        Some("tune") => qimeng::autotune::cli_tune(&args),
        Some("serve") => cmd_serve(&args),
        Some("profile") => cmd_profile(&args),
        Some(other) => Err(format!("unknown subcommand `{other}`")),
        None => {
            println!("{}", USAGE);
            Ok(())
        }
    }
}

const USAGE: &str = "\
tlc — QiMeng-Attention (ACL 2025) reproduction pipeline

USAGE: tlc <generate|generate-all|verify|ablate|tables|tune|serve|profile> [flags]

  generate     --variant mha|gqa|mqa|mla [--seq N] [--head-dim 64|128]
               [--causal] [--target a100|rtx8000|t4|l40s]
               [--llm deepseek-v3|deepseek-r1|claude-3.5|gpt-4o|gpt-4o+v3]
               [--backend pallas|cute] [--out FILE] [--show sketch|tl|all]
               [--autotune] [--cache FILE]
               [--kv-layout contiguous|paged|sliding] [--page-size N]
               [--window N] — paged emits block-table-gathered K/V loads
               (verified bit-identical to contiguous under an identity
               table); sliding clips the KV sweep to the trailing window
               [--direction forward|backward] (or --backward) — backward
               generates the FlashAttention-2 dQ/dK/dV bundle: three
               verified block programs emitted as one module behind a
               custom-VJP-shaped attention_backward host wrapper
               [--pattern dense|block-sparse|window-global] [--block N]
               [--topk N] [--n-global N] [--kv-len N] — block-sparse
               gathers the top-k selected KV blocks through a selection
               table (verified against the masked-dense oracle, and
               bitwise equal to dense when every tile is selected);
               window-global attends the trailing window plus n-global
               leading keys; --kv-len decouples the KV length from the
               query length (cross-attention shapes)
  generate-all [--out-dir python/compile/kernels/generated]
  verify       same operator flags as generate
  ablate       --failure reshape|gemm [operator flags]
  tables       --table 1|2|3|4|5|6|7|8|9 | --figure 1 | --all
  tune         [operator flags incl. --kv-layout/--page-size/--window]
               [--target ...] [--backend pallas|cute]
               [--grid] [--strategy auto|exhaustive|beam|greedy] [--seed N]
               [--measure] [--cache tune_cache.txt]
               --report prints observed-vs-modeled disagreement per
               cached shape (serving-mean latency vs cost-model rank)
               and the aggregate calibration disagreement instead of
               tuning; --calibrate fits the cost model's gemm/softmax/
               membw time multipliers to the cache's observations and
               persists them beside the cache (tune_cache.calib.txt) —
               later tunes auto-load the fit and rank by the calibrated
               model (combine with --report for pre/post numbers)
  serve        [--artifacts artifacts] [--requests N] [--rate-hz F]
               [--window-ms N] [--seed N] [--shards N] [--decode-frac F]
               [--executor pjrt|reference] [--kv-budget-mb N]
               [--kv-layout contiguous|paged|sliding] [--page-size N]
               [--window N] — decode-lane families take the layout; the
               KV budget clamps on pages actually resident (paged pool)
               --shards N spreads execution over N router-fed executor
               shards; --decode-frac F sends that fraction of traffic as
               decode-shaped requests (packed on the decode lane into
               split-K variants, KV-budget-aware). Measured per-variant
               latencies are folded back into artifacts/tune.txt.
               [--metrics-out FILE] writes the Prometheus text exposition
               on shutdown; [--trace-out FILE] enables span tracing and
               writes a Chrome trace (Perfetto / chrome://tracing);
               [--stats-every N] prints a metrics summary (and refreshes
               --metrics-out) every N executed batches
               [--deadline-ms N] sheds requests whose deadline passes
               (Timeout outcome); [--max-attempts N] bounds retries of
               failed executions; [--fault-plan \"error-rate=0.1,...\"]
               injects deterministic seeded faults (keys: seed,
               error-rate, panic-rate, spike-rate, spike-ms,
               kv-exhaust-rate) for chaos/recovery testing
  profile      [operator flags] [--requests N] [--artifacts DIR]
               [--trace-out trace.json] [--metrics-out FILE]
               traces one pipeline run, profiles the compiled engine per
               op kind (observed vs modeled shares), smokes the serving
               coordinator, prints a span rollup and writes the trace
";

fn spec_from(args: &Args) -> Result<OpSpec, String> {
    OpSpec::from_cli(args)
}

fn arch_from(args: &Args) -> Result<GpuArch, String> {
    GpuArch::from_cli(args)
}

fn profile_from(args: &Args) -> Result<LlmProfile, String> {
    Ok(match args.get_or("llm", "deepseek-v3").to_ascii_lowercase().as_str() {
        "deepseek-v3" | "v3" => LlmProfile::deepseek_v3(),
        "deepseek-r1" | "r1" => LlmProfile::deepseek_r1(),
        "claude-3.5" | "claude" => LlmProfile::claude35(),
        "gpt-4o" | "4o" => LlmProfile::gpt4o(),
        "gpt-4o+v3" | "4o+v3" => LlmProfile::gpt4o_plus_v3(),
        other => return Err(format!("unknown --llm `{other}`")),
    })
}

fn cmd_generate(args: &Args) -> Result<(), String> {
    let spec = spec_from(args)?;
    let arch = arch_from(args)?;
    let profile = profile_from(args)?;
    let backend = Target::from_cli(args)?;
    let show = args.get_or("show", "code").to_string();
    let out = args.get("out").map(String::from);
    let autotune = args.get_bool("autotune");
    let cache = args.get("cache").map(String::from);
    args.finish()?;

    let result = if autotune {
        let mut tuner = qimeng::autotune::Autotuner::new(qimeng::autotune::AutotuneConfig {
            cache_path: cache.map(std::path::PathBuf::from),
            ..Default::default()
        })
        .map_err(|e| format!("{e:#}"))?;
        let r = pipeline::run_tuned(&spec, &arch, &profile, backend, &mut tuner)
            .map_err(|e| e.to_string())?;
        tuner.save().map_err(|e| format!("{e:#}"))?;
        if let Some(t) = &r.tune {
            eprintln!(
                "autotune: {} via {}{} — modeled {:.1} us ({:.1} TFLOPS), search {:.2?}",
                t.candidate,
                t.strategy,
                if t.cached { " (cache hit)" } else { "" },
                t.seconds * 1e6,
                t.estimate.tflops,
                r.timings.search,
            );
        }
        r
    } else {
        pipeline::run(&spec, &arch, &profile, backend).map_err(|e| e.to_string())?
    };
    if show == "sketch" || show == "all" {
        println!("==== TL Sketch ({} stmts) ====", result.sketch.stmt_count());
        println!("{}", print_program(&result.sketch));
    }
    if show == "tl" || show == "all" {
        println!("==== TL Code ({} stmts) ====", result.reasoned.program.stmt_count());
        println!("{}", print_program(&result.reasoned.program));
        // Backward runs: the dQ program printed above is the primary;
        // show the rest of the bundle too.
        for (grad, part) in &result.backward {
            if part.program.name == result.reasoned.program.name {
                continue;
            }
            println!(
                "==== TL Code [{grad}] ({} stmts) ====",
                part.program.stmt_count()
            );
            println!("{}", print_program(&part.program));
        }
    }
    let source = result.source.unwrap();
    match out {
        Some(path) => {
            std::fs::write(&path, &source).map_err(|e| format!("write {path}: {e}"))?;
            eprintln!(
                "wrote {path} ({} lines); pipeline {:.2?}; tiling BM={} BN={} smem={}B",
                source.lines().count(),
                result.timings.total(),
                result.reasoned.tiling.bm,
                result.reasoned.tiling.bn,
                result.reasoned.tiling.smem_bytes,
            );
        }
        None => {
            if show == "code" || show == "all" {
                println!("{source}");
            }
        }
    }
    Ok(())
}

/// The standard kernel set consumed by `python/compile/aot.py`: every
/// (variant, head-dim, causal) family of the paper's main tables plus MLA.
pub fn standard_kernel_set() -> Vec<OpSpec> {
    let mut specs = Vec::new();
    for variant in [AttnVariant::Mha, AttnVariant::Gqa, AttnVariant::Mqa] {
        for head_dim in [64, 128] {
            for causal in [false, true] {
                specs.push(OpSpec::benchmark(variant, 1024, head_dim, causal));
            }
        }
    }
    specs.push(OpSpec::mla(1024, true));
    specs
}

fn cmd_generate_all(args: &Args) -> Result<(), String> {
    let out_dir = args.get_or("out-dir", "python/compile/kernels/generated").to_string();
    let arch = arch_from(args)?;
    let profile = profile_from(args)?;
    args.finish()?;

    std::fs::create_dir_all(&out_dir).map_err(|e| format!("mkdir {out_dir}: {e}"))?;
    let mut manifest = String::from("# kernels emitted by `tlc generate-all`\n");
    let mut init = String::from(
        "\"\"\"AUTO-GENERATED kernel package (tlc generate-all). DO NOT EDIT.\"\"\"\n",
    );
    let specs = standard_kernel_set();
    let n = specs.len();
    for spec in &specs {
        let result = pipeline::run(spec, &arch, &profile, Target::Pallas)
            .map_err(|e| format!("{}: {e}", spec.kernel_name()))?;
        let name = spec.kernel_name();
        let path = format!("{out_dir}/{name}.py");
        std::fs::write(&path, result.source.unwrap())
            .map_err(|e| format!("write {path}: {e}"))?;
        manifest.push_str(&format!(
            "{name} bm={} bn={} verify_diff={:.3e}\n",
            result.reasoned.tiling.bm,
            result.reasoned.tiling.bn,
            result.verify.max_abs_diff.unwrap_or(f32::NAN),
        ));
        init.push_str(&format!("from . import {name}  # noqa: F401\n"));
        eprintln!(
            "generated {name}: BM={} BN={} verified diff {:.2e} in {:.1?}",
            result.reasoned.tiling.bm,
            result.reasoned.tiling.bn,
            result.verify.max_abs_diff.unwrap_or(f32::NAN),
            result.timings.total()
        );
    }
    std::fs::write(format!("{out_dir}/MANIFEST.txt"), manifest)
        .map_err(|e| format!("write manifest: {e}"))?;
    std::fs::write(format!("{out_dir}/__init__.py"), init)
        .map_err(|e| format!("write __init__: {e}"))?;
    eprintln!("generated {n} kernels into {out_dir}");
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<(), String> {
    let spec = spec_from(args)?;
    let arch = arch_from(args)?;
    let profile = profile_from(args)?;
    args.finish()?;
    match pipeline::run(&spec, &arch, &profile, Target::Pallas) {
        Ok(r) => {
            println!(
                "PASS {}: diagnostics 0, numeric probe max|diff| = {:.3e} (tol {:.0e})",
                spec.kernel_name(),
                r.verify.max_abs_diff.unwrap_or(f32::NAN),
                qimeng::verify::NUMERIC_TOL,
            );
            Ok(())
        }
        Err(e) => Err(e.to_string()),
    }
}

fn cmd_ablate(args: &Args) -> Result<(), String> {
    let spec = spec_from(args)?;
    let arch = arch_from(args)?;
    let failure = match args.get_or("failure", "reshape") {
        "reshape" => FailureMode::ReshapeOmission,
        "gemm" => FailureMode::GemmLayoutError,
        other => return Err(format!("unknown --failure `{other}` (reshape|gemm)")),
    };
    args.finish()?;
    let profile = LlmProfile::single_stage(LlmProfile::deepseek_v3(), failure);
    match pipeline::run(&spec, &arch, &profile, Target::Pallas) {
        Err(e) => {
            println!("single-stage generation rejected (as in paper Appendix B):\n{e}");
            Ok(())
        }
        Ok(_) => Err("ablation unexpectedly passed verification".into()),
    }
}

fn cmd_tables(args: &Args) -> Result<(), String> {
    qimeng::report::cli_tables(args)
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    qimeng::coordinator::cli_serve(args)
}

/// `tlc profile`: one traced pass over all three layers — a pipeline
/// run (`pipeline.*` spans), the compiled engine's op-level profiling
/// mode (observed-vs-modeled table), and a serving smoke through the
/// reference executor (`serve.*` spans) — then a span rollup and a
/// Chrome trace ready for Perfetto / `chrome://tracing`.
fn cmd_profile(args: &Args) -> Result<(), String> {
    use qimeng::coordinator::{Coordinator, ExecutorSpec, ServeConfig};

    let spec = spec_from(args)?;
    let arch = arch_from(args)?;
    let profile = profile_from(args)?;
    let backend = Target::from_cli(args)?;
    let artifacts = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let n = args.get_usize("requests", 32)?;
    let trace_out = args.get_or("trace-out", "trace.json").to_string();
    let metrics_out = args.get("metrics-out").map(String::from);
    args.finish()?;

    qimeng::obs::set_enabled(true);

    // Layer 1: the generation pipeline (sketch → reason → verify →
    // translate), traced as pipeline.* spans.
    let r = pipeline::run(&spec, &arch, &profile, backend).map_err(|e| e.to_string())?;
    println!(
        "pipeline: {} generated and verified in {:.2?} (probe max|diff| {:.2e})",
        spec.kernel_name(),
        r.timings.total(),
        r.verify.max_abs_diff.unwrap_or(f32::NAN),
    );
    println!();

    // Layer 2: the compiled engine's op-level profiling mode, compared
    // against the analytical cost model's per-term attribution.
    qimeng::autotune::op_profile_report(&spec, &arch)?;
    println!();

    // Layer 3: a short serving smoke (reference executor, synthetic
    // stream) so the trace covers the request lifecycle too.
    let coordinator = Coordinator::start(ServeConfig {
        artifacts_dir: artifacts,
        shards: 2,
        executor: ExecutorSpec::Reference,
        ..ServeConfig::default()
    })
    .map_err(|e| format!("{e:#}"))?;
    let stream =
        qimeng::workload::request_stream_mixed(&coordinator.families, n, 400.0, 0.5, 7);
    let report = qimeng::coordinator::run_stream(&coordinator, &stream, 1.0);
    println!(
        "serve smoke: {} requests over {} shard(s): {} ok, {} errors, p95 {:.2?}",
        report.requests,
        coordinator.shards(),
        report.ok,
        report.errors,
        report.p95,
    );
    let metrics_text = qimeng::coordinator::metrics_exposition(&coordinator.metrics);
    coordinator.shutdown();

    let spans = qimeng::obs::global().spans();
    let rows = qimeng::obs::export::rollup(&spans);
    println!();
    println!("span rollup ({} spans):", spans.len());
    println!("{:<20} {:>7} {:>12} {:>12}", "span", "count", "total us", "max us");
    for row in &rows {
        println!("{:<20} {:>7} {:>12} {:>12}", row.name, row.count, row.total_us, row.max_us);
    }

    std::fs::write(&trace_out, qimeng::obs::export::chrome_trace(&spans))
        .map_err(|e| format!("write {trace_out}: {e}"))?;
    println!();
    println!(
        "wrote Chrome trace ({} events) -> {trace_out} (open in Perfetto or chrome://tracing)",
        spans.len()
    );
    if let Some(p) = metrics_out {
        std::fs::write(&p, metrics_text).map_err(|e| format!("write {p}: {e}"))?;
        println!("wrote Prometheus metrics -> {p}");
    }
    Ok(())
}
