//! GPU architecture descriptors for the cards the paper evaluates on.
//!
//! These are the public datasheet numbers (peak Tensor-Core throughput,
//! memory bandwidth, SM counts, shared-memory sizes). The stage-1b
//! reasoner uses the shared-memory budget and Tensor-Core tile shape to
//! pick `BM`/`BN`; the analytical performance model uses the full
//! descriptor to price a TL schedule (DESIGN.md §2 explains why a machine
//! model substitutes for the physical cards in this environment).

use std::fmt;

/// NVIDIA GPU generation (instruction set family). Determines which mma
/// shapes CuTe can use and whether FlashAttention v2 is available (the
/// official library does not support Turing — §4.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GpuGeneration {
    Ampere,
    Turing,
    /// Ada Lovelace (L40S) — adds FP8 Tensor Cores (Table 6).
    Ada,
}

/// One GPU target.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuArch {
    pub name: &'static str,
    pub generation: GpuGeneration,
    pub sm_count: usize,
    /// SM boost clock in GHz.
    pub clock_ghz: f64,
    /// Peak dense Tensor-Core throughput for FP16 inputs with FP32
    /// accumulate, in TFLOPS.
    pub tc_tflops_f16: f64,
    /// Peak FP8 Tensor-Core TFLOPS (0 when unsupported).
    pub tc_tflops_f8: f64,
    /// Peak non-TensorCore FP32 CUDA-core TFLOPS (softmax, exp, pointwise
    /// run here).
    pub cuda_tflops_f32: f64,
    /// Device memory bandwidth, GB/s.
    pub mem_bw_gbs: f64,
    /// Shared memory per SM, bytes (configurable carve-out maximum).
    pub smem_per_sm: usize,
    /// Maximum shared memory a single thread block may use, bytes.
    pub smem_per_block: usize,
    /// Register file per SM, bytes.
    pub regfile_per_sm: usize,
    /// L2 cache size, bytes.
    pub l2_bytes: usize,
    /// Shared-memory bandwidth per SM, bytes/clock (for staging cost).
    pub smem_bytes_per_clk: f64,
    /// Device memory capacity, GiB (OOM modelling for the unfused
    /// vanilla-LLM baseline).
    pub mem_gib: f64,
}

impl GpuArch {
    /// NVIDIA A100-SXM4-80GB (Ampere, the paper's primary card).
    pub fn a100() -> Self {
        GpuArch {
            name: "A100",
            generation: GpuGeneration::Ampere,
            sm_count: 108,
            clock_ghz: 1.41,
            tc_tflops_f16: 312.0,
            tc_tflops_f8: 0.0,
            cuda_tflops_f32: 19.5,
            mem_bw_gbs: 2039.0,
            smem_per_sm: 164 * 1024,
            smem_per_block: 163 * 1024,
            regfile_per_sm: 256 * 1024,
            l2_bytes: 40 * 1024 * 1024,
            smem_bytes_per_clk: 128.0,
            mem_gib: 80.0,
        }
    }

    /// Quadro RTX 8000 (Turing). FlashAttention v2 does not support this
    /// generation; the paper compares against flash-attn v1 here.
    pub fn rtx8000() -> Self {
        GpuArch {
            name: "RTX8000",
            generation: GpuGeneration::Turing,
            sm_count: 72,
            clock_ghz: 1.77,
            tc_tflops_f16: 130.5,
            tc_tflops_f8: 0.0,
            cuda_tflops_f32: 16.3,
            mem_bw_gbs: 672.0,
            smem_per_sm: 64 * 1024,
            smem_per_block: 64 * 1024,
            regfile_per_sm: 256 * 1024,
            l2_bytes: 6 * 1024 * 1024,
            smem_bytes_per_clk: 64.0,
            mem_gib: 48.0,
        }
    }

    /// Tesla T4 (Turing, the paper's low-power card, Table 7).
    pub fn t4() -> Self {
        GpuArch {
            name: "T4",
            generation: GpuGeneration::Turing,
            sm_count: 40,
            clock_ghz: 1.59,
            tc_tflops_f16: 65.0,
            tc_tflops_f8: 0.0,
            cuda_tflops_f32: 8.1,
            mem_bw_gbs: 320.0,
            smem_per_sm: 64 * 1024,
            smem_per_block: 64 * 1024,
            regfile_per_sm: 256 * 1024,
            l2_bytes: 4 * 1024 * 1024,
            smem_bytes_per_clk: 64.0,
            mem_gib: 16.0,
        }
    }

    /// L40S (Ada) — the FP8 case study of Table 6.
    pub fn l40s() -> Self {
        GpuArch {
            name: "L40S",
            generation: GpuGeneration::Ada,
            sm_count: 142,
            clock_ghz: 2.52,
            tc_tflops_f16: 362.0,
            tc_tflops_f8: 366.0,  // dense (733 is the 2:4-sparsity marketing number)
            cuda_tflops_f32: 91.6,
            mem_bw_gbs: 864.0,
            smem_per_sm: 100 * 1024,
            smem_per_block: 99 * 1024,
            regfile_per_sm: 256 * 1024,
            l2_bytes: 96 * 1024 * 1024,
            smem_bytes_per_clk: 128.0,
            mem_gib: 48.0,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "a100" => Some(Self::a100()),
            "rtx8000" => Some(Self::rtx8000()),
            "t4" => Some(Self::t4()),
            "l40s" => Some(Self::l40s()),
            _ => None,
        }
    }

    pub fn all() -> Vec<Self> {
        vec![Self::a100(), Self::rtx8000(), Self::t4(), Self::l40s()]
    }

    /// Parse the `--target` CLI flag (shared by the `tlc` subcommands).
    pub fn from_cli(args: &crate::util::cli::Args) -> Result<Self, String> {
        let name = args.get_or("target", "a100");
        Self::by_name(name).ok_or_else(|| format!("unknown --target `{name}`"))
    }

    /// Peak Tensor-Core TFLOPS for a given element width (bytes).
    pub fn tc_tflops(&self, dtype_bytes: usize) -> f64 {
        match dtype_bytes {
            1 if self.tc_tflops_f8 > 0.0 => self.tc_tflops_f8,
            _ => self.tc_tflops_f16,
        }
    }

    /// Does the official FlashAttention v2 support this generation?
    /// (v2 requires Ampere+; on Turing the paper falls back to v1.)
    pub fn supports_flash_v2(&self) -> bool {
        !matches!(self.generation, GpuGeneration::Turing)
    }
}

impl fmt::Display for GpuArch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} ({:?}, {} SMs, {:.0} TFLOPS fp16-TC, {:.0} GB/s)",
            self.name, self.generation, self.sm_count, self.tc_tflops_f16, self.mem_bw_gbs
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        assert_eq!(GpuArch::by_name("A100").unwrap().name, "A100");
        assert_eq!(GpuArch::by_name("rtx8000").unwrap().generation, GpuGeneration::Turing);
        assert!(GpuArch::by_name("h100").is_none());
    }

    #[test]
    fn flash_v2_support_matches_paper() {
        assert!(GpuArch::a100().supports_flash_v2());
        assert!(!GpuArch::rtx8000().supports_flash_v2());
        assert!(!GpuArch::t4().supports_flash_v2());
    }

    #[test]
    fn fp8_only_on_ada() {
        assert!(GpuArch::l40s().tc_tflops(1) > GpuArch::l40s().tc_tflops(2));
        // Cards without FP8 fall back to the f16 path.
        assert_eq!(GpuArch::a100().tc_tflops(1), GpuArch::a100().tc_tflops(2));
    }

    #[test]
    fn rooflines_ordered_as_expected() {
        // A100 > RTX8000 > T4 in both compute and bandwidth.
        let (a, r, t) = (GpuArch::a100(), GpuArch::rtx8000(), GpuArch::t4());
        assert!(a.tc_tflops_f16 > r.tc_tflops_f16 && r.tc_tflops_f16 > t.tc_tflops_f16);
        assert!(a.mem_bw_gbs > r.mem_bw_gbs && r.mem_bw_gbs > t.mem_bw_gbs);
    }
}
