//! Analytical cost model: prices an attention *schedule* on a GPU
//! descriptor and reports achieved TFLOPS the way the paper's tables do.
//!
//! Structure (validated against the paper's own measurements — see
//! `report::paper` for the anchor comparison tests):
//!
//! * **Fused (flash-style) schedules**: each (batch, head, q-block)
//!   thread block visits `nkv` KV tiles (halved by causal block
//!   skipping); per-tile cost = two mma GEMMs at a calibrated pipeline
//!   efficiency + the exposed (non-overlapped) softmax/mask work on CUDA
//!   cores; plus an epilogue worth ~`c_epi` KV-tile iterations — this
//!   epilogue amortization is what makes TFLOPS rise with sequence
//!   length in every column of Table 1.
//! * **Unfused (torch-style) schedules**: bandwidth-bound on the
//!   materialized f32 score/probability matrices. Fitting the paper's
//!   vanilla rows gives a remarkably consistent ~16.5 effective passes
//!   over S across A100/RTX8000/T4 (eager softmax chains), which this
//!   model adopts; OOM is declared when the intermediates exceed device
//!   memory, reproducing the paper's OOM cells exactly.
//!
//! Calibration: one mma-efficiency scalar per (schedule kind, GPU
//! generation, head-dim bucket), anchored at the paper's seq=16k causal
//! measurements; everything else (the other five sequence lengths,
//! non-causal, crossovers, OOM) is *predicted* by the model.

use super::calibrate::Calibration;
use super::gpu::GpuArch;
use crate::sketch::spec::{Direction, KvLayout, OpSpec, ScorePattern};

/// Backward-over-forward GEMM ratio per score tile: the FlashAttention-2
/// backward runs five GEMMs (S recompute, dP, dV, dK, dQ) where the
/// forward runs two — the same 2.5x [`OpSpec::flops`] reports.
const BWD_GEMM_RATIO: f64 = 2.5;

/// Schedule kind — determines the calibration row and structural path.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SchedKind {
    /// The paper's pipeline output (DeepSeek-V3 + Ours by default).
    Ours,
    OursFp8,
    FlashV2,
    FlashV1,
    CuDnn,
    FlexAttention,
    /// Unfused vanilla-LLM (torch eager) implementation.
    TorchNaive,
    /// DeepSeek's open-source torch MLA (einsum chain, better than eager).
    TorchMla,
    /// Chain-of-thought CUDA-core kernel (Table 5): no Tensor Cores.
    CotCuda,
}

/// A fully-parameterized schedule to price.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub kind: SchedKind,
    pub name: String,
    pub bm: usize,
    pub bn: usize,
    pub tensor_core: bool,
    /// Single fused pass (no S materialization in HBM).
    pub fused: bool,
    /// Causal block skipping (visit only the lower-triangular KV tiles).
    pub causal_block_skip: bool,
    /// Fraction of softmax/pointwise time hidden under the mma pipeline.
    pub softmax_overlap: f64,
    /// Epilogue + prologue cost in units of KV-tile iterations.
    pub c_epi: f64,
    /// Calibrated mma pipeline efficiency (fraction of peak TC FLOPS).
    pub mma_eff: f64,
    /// Unfused only: effective f32 passes over the S matrix.
    pub unfused_passes: f64,
}

/// Model output for one (spec, arch, schedule) cell.
#[derive(Debug, Clone)]
pub struct Estimate {
    pub seconds: f64,
    /// Achieved TFLOPS using the paper's FLOP formula (0 when OOM).
    pub tflops: f64,
    pub dram_gb: f64,
    pub oom: bool,
}

impl Estimate {
    pub fn oom() -> Self {
        Estimate { seconds: f64::INFINITY, tflops: 0.0, dram_gb: 0.0, oom: true }
    }
}

const KERNEL_LAUNCH_S: f64 = 5e-6;

/// The model's wall-clock decomposed into the three calibratable time
/// components ([`super::calibrate`]): GEMM compute, exposed softmax /
/// pointwise work, and DRAM traffic. [`CostTerms::seconds_with`]
/// recombines them exactly as [`estimate`] does — with the identity
/// [`Calibration`] the result is bit-identical, which is what keeps the
/// paper-anchored tests meaningful after calibration was bolted on.
///
/// Fused schedules keep the per-KV-tile granularity (`gemm`/`softmax`
/// are *per-tile* seconds, scaled by `tile_iters` and `blocks` at
/// recombination time) so the float grouping of the original formula is
/// preserved; unfused schedules fold everything into `gemm` with
/// `tile_iters = blocks = 1`.
#[derive(Debug, Clone, Copy)]
pub struct CostTerms {
    /// Schedule could not run at all (unfused intermediates exceed
    /// device memory); every time component is zero.
    pub oom: bool,
    /// Fused combine is `max(compute, mem)`; unfused is the sum.
    pub fused: bool,
    /// Fused: mma seconds per KV tile. Unfused: whole-pass GEMM seconds
    /// (including the MLA decompress einsums).
    pub gemm: f64,
    /// Fused: exposed softmax/mask seconds per KV tile. Unfused: 0 (the
    /// pointwise chain is priced as S-matrix traffic there).
    pub softmax: f64,
    /// DRAM-traffic seconds at the descriptor's peak bandwidth.
    pub mem: f64,
    /// Fused: KV-tile iterations per thread block (`nkv + c_epi`);
    /// unfused: 1.
    pub tile_iters: f64,
    /// Fused: thread blocks in the sweep; unfused: 1.
    pub blocks: f64,
    /// Kernel-launch overhead seconds — deliberately *not* calibrated.
    pub overhead: f64,
    /// Modeled DRAM traffic in bytes (reported as `dram_gb`).
    pub traffic: f64,
    /// The paper's FLOP count for the op (reported as `tflops`).
    pub flops: f64,
}

impl CostTerms {
    /// The all-zero OOM marker.
    pub const fn oom() -> Self {
        CostTerms {
            oom: true,
            fused: false,
            gemm: 0.0,
            softmax: 0.0,
            mem: 0.0,
            tile_iters: 0.0,
            blocks: 0.0,
            overhead: 0.0,
            traffic: 0.0,
            flops: 0.0,
        }
    }

    /// Recombine into wall-clock seconds under `cal`. The identity
    /// calibration reproduces [`estimate`]'s arithmetic bit-for-bit
    /// (`x * 1.0 == x` and `x + 0.0 == x` exactly in IEEE-754).
    pub fn seconds_with(&self, cal: &Calibration) -> f64 {
        let compute = self.blocks
            * (self.tile_iters * (self.gemm * cal.gemm + self.softmax * cal.softmax));
        let mem = self.mem * cal.membw;
        if self.fused {
            compute.max(mem) + self.overhead
        } else {
            mem + compute + self.overhead
        }
    }

    /// The three fully-scaled identity-calibration time components
    /// `(gemm, softmax, mem)` in seconds — the feature vector the
    /// least-squares fit consumes ([`super::calibrate::FitSample`]).
    pub fn components(&self) -> (f64, f64, f64) {
        (
            self.blocks * self.tile_iters * self.gemm,
            self.blocks * self.tile_iters * self.softmax,
            self.mem,
        )
    }
}

/// Attended score-rectangle elements for a spec: the (query, key) score
/// entries the pattern actually computes, summed over batch and query
/// heads. Dense counts the full `seq × kv` rectangle (causality is a
/// schedule optimization, not a pattern — Table 9's eager baseline pays
/// for the whole rectangle either way); block-sparse counts `topk ×
/// block` keys per query; window+global counts `window + n_global`.
/// This is the single rectangle model [`super::nsa::nsa_latency_s`]
/// prices — any per-element cost model belongs on top of this, not
/// duplicated beside it.
pub fn score_rect_elems(spec: &OpSpec) -> f64 {
    let bh = (spec.batch * spec.num_q_heads) as f64;
    let kv = spec.kv_len as f64;
    let per_query = match spec.pattern {
        ScorePattern::Dense => kv,
        ScorePattern::BlockSparse { block, topk } => ((topk * block) as f64).min(kv),
        ScorePattern::WindowGlobal { window, n_global } => {
            ((window + n_global) as f64).min(kv)
        }
    };
    bh * spec.seq_len as f64 * per_query
}

/// Mean number of KV tiles visited per q-block under causal block
/// skipping: mean over q-blocks of ceil((i+1)*BM / BN).
fn mean_causal_kv_tiles(seq: usize, kv: usize, bm: usize, bn: usize) -> f64 {
    let nqb = (seq / bm).max(1);
    let mut total = 0.0;
    for i in 0..nqb {
        let tiles = (((i + 1) * bm + bn - 1) / bn).min(kv / bn.max(1));
        total += tiles as f64;
    }
    total / nqb as f64
}

/// Price one cell.
pub fn estimate(spec: &OpSpec, arch: &GpuArch, sched: &Schedule) -> Estimate {
    estimate_calibrated(spec, arch, sched, &Calibration::identity())
}

/// Price one cell under a fitted [`Calibration`]: the same structural
/// model with each time component scaled by its fitted multiplier. The
/// identity calibration reproduces [`estimate`] exactly, so the
/// paper-anchored tests pin this path too.
pub fn estimate_calibrated(
    spec: &OpSpec,
    arch: &GpuArch,
    sched: &Schedule,
    cal: &Calibration,
) -> Estimate {
    let t = cost_terms(spec, arch, sched);
    if t.oom {
        return Estimate::oom();
    }
    let seconds = t.seconds_with(cal);
    Estimate {
        seconds,
        tflops: t.flops / seconds / 1e12,
        dram_gb: t.traffic / 1e9,
        oom: false,
    }
}

/// Decompose one cell into its calibratable time components — the
/// shared core of [`estimate`] / [`estimate_calibrated`] and the
/// feature extractor for the calibration fit.
pub fn cost_terms(spec: &OpSpec, arch: &GpuArch, sched: &Schedule) -> CostTerms {
    let b = spec.batch as f64;
    let h = spec.num_q_heads as f64;
    let s = spec.seq_len as f64;
    let kv = spec.kv_len as f64;
    let e = spec.dtype.bytes() as f64;
    let gemm_width = (spec.qk_dim() + spec.v_head_dim) as f64;

    // ---- OOM check for unfused schedules ----
    // Peak live set in eager torch: the f16 score matrix S plus the f32
    // softmax output held simultaneously = 6 bytes per score element.
    // This single rule reproduces every OOM cell of Tables 1 and 7
    // (RTX8000@16k-hd64, T4@{8k,16k}-hd64, T4@16k-hd128, A100 never).
    if !sched.fused {
        let intermediates = b * h * s * kv * 6.0;
        let weights_inputs = spec.io_bytes() as f64;
        if intermediates + weights_inputs > arch.mem_gib * 1024.0 * 1024.0 * 1024.0 {
            return CostTerms::oom();
        }
    }

    let reported_flops = spec.flops();

    if !sched.fused {
        // Bandwidth-bound unfused path. A causal mask in eager torch
        // materializes the mask tensor and runs `where`, nearly doubling
        // the S-matrix traffic (this reproduces the paper's ~4x gap
        // between the causal and non-causal vanilla rows). The unfused
        // backward materializes S, P, dP and dS, so its effective pass
        // count scales with the 5-GEMM ratio.
        let mask_factor =
            if spec.causal && sched.kind == SchedKind::TorchNaive { 1.9 } else { 1.0 };
        let bwd_passes =
            if spec.direction == Direction::Backward { BWD_GEMM_RATIO } else { 1.0 };
        let s_bytes = b * h * s * kv * 4.0;
        let traffic =
            spec.io_bytes() as f64 + sched.unfused_passes * bwd_passes * mask_factor * s_bytes;
        let t_mem = traffic / (arch.mem_bw_gbs * 1e9);
        // Compute floor (matmuls still run, on TC or CUDA cores).
        let peak = if sched.tensor_core {
            arch.tc_tflops(spec.dtype.bytes()) * 1e12
        } else {
            arch.cuda_tflops_f32 * 1e12
        };
        // Unfused computes the full rectangle even under a causal mask.
        let executed = 2.0 * b * s * kv * h * gemm_width * bwd_passes;
        let mut t_compute = executed / (peak * sched.mma_eff);
        // MLA: the latent KV decompression einsums are extra GEMM work
        // proportional to total tokens (constant across the sweep — this
        // is what makes the torch-MLA row of Table 2 rise with seq).
        if spec.latent_dim > 0 {
            let decompress = 2.0
                * b
                * kv
                * spec.latent_dim as f64
                * h
                * (spec.head_dim + spec.v_head_dim) as f64;
            t_compute += decompress / (peak * 0.5);
        }
        return CostTerms {
            oom: false,
            fused: false,
            gemm: t_compute,
            softmax: 0.0,
            mem: t_mem,
            tile_iters: 1.0,
            blocks: 1.0,
            overhead: KERNEL_LAUNCH_S * 8.0,
            traffic,
            flops: reported_flops,
        };
    }

    // ---- fused flash-style path ----
    let bm = sched.bm.min(spec.seq_len).max(1);
    let bn = sched.bn.min(spec.kv_len).max(1);
    let nqb = (spec.seq_len / bm).max(1) as f64;
    let blocks = b * h * nqb;

    let nkv = if spec.causal && sched.causal_block_skip {
        mean_causal_kv_tiles(spec.seq_len, spec.kv_len, bm, bn)
    } else {
        kv / bn as f64
    };
    // Sliding layout: whole tiles below the window are skipped, so each
    // q-block visits at most the window's tiles (plus one boundary tile).
    let nkv = match spec.kv_layout {
        KvLayout::Sliding { window } => nkv.min((window as f64 / bn as f64).ceil() + 1.0),
        _ => nkv,
    };
    // Score-pattern clip: sparse patterns visit only their score
    // rectangle's tiles. Block-sparse streams exactly the selected
    // tiles; window+global streams the trailing window (plus one
    // boundary tile) and the leading global tiles. The Dense arm is an
    // arithmetic no-op — the identity-recombine tests pin the dense
    // bits, so no float op may touch that path.
    let nkv = match spec.pattern {
        ScorePattern::Dense => nkv,
        ScorePattern::BlockSparse { block, topk } => {
            nkv.min(((topk * block) as f64 / bn as f64).ceil().max(1.0))
        }
        ScorePattern::WindowGlobal { window, n_global } => nkv.min(
            (window as f64 / bn as f64).ceil() + 1.0 + (n_global as f64 / bn as f64).ceil(),
        ),
    };

    // Per-KV-tile mma work (both GEMMs; the backward's five-GEMM
    // recompute scales it by [`BWD_GEMM_RATIO`]). Times are aggregate:
    // total tile work over the whole-GPU peak (full occupancy assumed;
    // the paper's grids always have thousands of thread blocks for 108
    // SMs).
    let backward = spec.direction == Direction::Backward;
    let gemm_ratio = if backward { BWD_GEMM_RATIO } else { 1.0 };
    let tile_flops = 2.0 * (bm * bn) as f64 * gemm_width * gemm_ratio;
    let peak_tc = if sched.tensor_core {
        arch.tc_tflops(spec.dtype.bytes()) * 1e12
    } else {
        arch.cuda_tflops_f32 * 1e12
    };
    let t_tile_mma = tile_flops / (peak_tc * sched.mma_eff);

    // Softmax / mask / rescale on CUDA cores: ~5 f32 ops per score element
    // (+2 for mask index math under causal). The backward's pointwise
    // chain (exp recompute, row-broadcast subtracts, the Jacobian
    // Hadamard) roughly doubles it.
    let mut sm_ops_per_elem = if spec.causal { 7.0 } else { 5.0 };
    if backward {
        sm_ops_per_elem += 5.0;
    }
    let t_tile_sm = sm_ops_per_elem * (bm * bn) as f64
        / (arch.cuda_tflops_f32 * 1e12)
        * (1.0 - sched.softmax_overlap);

    // DRAM traffic: Q + O once; K/V streamed per q-block with partial L2
    // reuse (working set vs L2 capacity).
    let q_bytes = b * h * s * spec.qk_dim() as f64 * e;
    let o_bytes = b * h * s * spec.v_head_dim as f64 * e;
    // Paged-IO term: K/V reads are page-granular — boundary rows lose
    // coalescing (~2 rows' worth per page) and every page costs one
    // 8-byte block-table entry. Sliding caps the per-q-block stream at
    // the trailing window (plus the boundary tile) — and those pages are
    // all read at full rate, so the causal reread halving does NOT apply
    // to pages past the sliding window.
    let mut kv_bytes_head = kv * gemm_width * e;
    let mut causal_reread_half = if spec.causal { 0.5 } else { 1.0 };
    match spec.kv_layout {
        KvLayout::Contiguous => {}
        KvLayout::Paged { page_size } => {
            let page = page_size.max(1) as f64;
            kv_bytes_head = kv_bytes_head * (1.0 + 2.0 / page) + (kv / page) * 8.0;
        }
        KvLayout::Sliding { window } => {
            kv_bytes_head =
                kv_bytes_head.min((window as f64 + bn as f64) * gemm_width * e);
            causal_reread_half = 1.0;
        }
    }
    // Score-pattern traffic clip mirrors the tile clip: only attended
    // K/V rows stream through, plus one 8-byte selection-table entry
    // per gathered tile for block-sparse (the same shape as the paged
    // block-table term). Dense is untouched, bit-for-bit.
    match spec.pattern {
        ScorePattern::Dense => {}
        ScorePattern::BlockSparse { block, topk } => {
            let attended = ((topk * block) as f64).min(kv);
            let sel_tiles = ((topk * block) as f64 / bn as f64).ceil();
            kv_bytes_head = kv_bytes_head.min(attended * gemm_width * e) + sel_tiles * 8.0;
        }
        ScorePattern::WindowGlobal { window, n_global } => {
            let attended = ((window + n_global) as f64 + bn as f64).min(kv);
            kv_bytes_head = kv_bytes_head.min(attended * gemm_width * e);
            // Window rows are all read at full rate — the causal reread
            // halving is a dense-sweep artifact (same as Sliding).
            causal_reread_half = 1.0;
        }
    }
    let kv_heads = (spec.batch * spec.num_kv_heads) as f64;
    // Fraction of K/V rereads that miss L2: 0 when a head's K/V fits with
    // room for the concurrently-active heads, -> 1 as it overflows.
    let active = (arch.sm_count as f64 / nqb.max(1.0)).min(kv_heads).max(1.0);
    let l2_pressure = (kv_bytes_head * active) / arch.l2_bytes as f64;
    let miss = (l2_pressure / (1.0 + l2_pressure)).min(1.0);
    let reread = 1.0 + (nqb - 1.0).max(0.0) * miss * causal_reread_half;
    let mut traffic = q_bytes + o_bytes + kv_bytes_head * kv_heads * reread;
    if backward {
        // Recompute traffic: the backward streams Q and dO a second time
        // (the dK/dV kernels' q-sweep, subject to the same L2 model),
        // reads the per-row lse/delta stats, and writes dQ/dK/dV — but
        // never reads an O(n^2) intermediate back (the recompute trick).
        let stats_bytes = 2.0 * b * h * s * 4.0;
        let grads_out = q_bytes + kv_bytes_head * kv_heads;
        traffic += (q_bytes + o_bytes) * (1.0 + miss) + stats_bytes + grads_out;
    }
    let t_mem = traffic / (arch.mem_bw_gbs * 1e9);

    CostTerms {
        oom: false,
        fused: true,
        gemm: t_tile_mma,
        softmax: t_tile_sm,
        mem: t_mem,
        tile_iters: nkv + sched.c_epi,
        blocks,
        overhead: KERNEL_LAUNCH_S,
        traffic,
        flops: reported_flops,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::schedules;
    use crate::sketch::spec::AttnVariant;

    fn mha(seq: usize, hd: usize, causal: bool) -> OpSpec {
        OpSpec::benchmark(AttnVariant::Mha, seq, hd, causal)
    }

    #[test]
    fn causal_tile_mean() {
        // BM=BN: q-block i visits i+1 tiles; mean over 4 blocks = 2.5.
        assert!((mean_causal_kv_tiles(512, 512, 128, 128) - 2.5).abs() < 1e-9);
        // BM=128, BN=64: q-block i visits 2(i+1) tiles; mean = 5.
        assert!((mean_causal_kv_tiles(512, 512, 128, 64) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn tflops_rise_with_sequence_length() {
        let arch = GpuArch::a100();
        let sched = schedules::ours(&arch, 64, crate::tl::types::DType::F16);
        let mut prev = 0.0;
        for seq in [512, 1024, 2048, 4096, 8192, 16384] {
            let est = estimate(&mha(seq, 64, true), &arch, &sched);
            assert!(
                est.tflops > prev,
                "TFLOPS must rise with seq: {} at {seq}",
                est.tflops
            );
            prev = est.tflops;
        }
    }

    #[test]
    fn fused_never_oom_unfused_ooms_like_paper() {
        // Paper Table 1: vanilla OOMs at 16k on RTX8000 (48 GB) but not on
        // A100 (80 GB); fused never OOMs.
        let spec = mha(16384, 64, true);
        let rtx = GpuArch::rtx8000();
        let a100 = GpuArch::a100();
        let naive_rtx = estimate(&spec, &rtx, &schedules::torch_naive());
        let naive_a100 = estimate(&spec, &a100, &schedules::torch_naive());
        let ours_rtx =
            estimate(&spec, &rtx, &schedules::ours(&rtx, 64, crate::tl::types::DType::F16));
        assert!(naive_rtx.oom, "vanilla must OOM at 16k on RTX8000");
        assert!(!naive_a100.oom, "vanilla survives on 80 GB A100");
        assert!(!ours_rtx.oom);
    }

    #[test]
    fn t4_vanilla_oom_pattern_matches_table7() {
        // Table 7: hd64 vanilla OOMs at 8k & 16k; hd128 only at 16k.
        let t4 = GpuArch::t4();
        let naive = schedules::torch_naive();
        assert!(!estimate(&mha(4096, 64, true), &t4, &naive).oom);
        assert!(estimate(&mha(8192, 64, true), &t4, &naive).oom);
        assert!(estimate(&mha(16384, 64, true), &t4, &naive).oom);
        assert!(!estimate(&mha(8192, 128, true), &t4, &naive).oom);
        assert!(estimate(&mha(16384, 128, true), &t4, &naive).oom);
    }

    #[test]
    fn vanilla_is_bandwidth_bound_and_flat() {
        let arch = GpuArch::a100();
        let naive = schedules::torch_naive();
        let a = estimate(&mha(1024, 64, true), &arch, &naive);
        let b = estimate(&mha(8192, 64, true), &arch, &naive);
        let ratio = a.tflops / b.tflops;
        assert!((0.5..2.0).contains(&ratio), "vanilla should be roughly flat: {ratio}");
        assert!(a.tflops < 15.0, "vanilla must be slow: {}", a.tflops);
    }

    #[test]
    fn causal_block_skipping_wins_at_long_context() {
        // The paper's headline causal speedups require the skip: compare
        // ours against an identical schedule without skipping.
        let arch = GpuArch::a100();
        let spec = mha(16384, 64, true);
        let ours = schedules::ours(&arch, 64, crate::tl::types::DType::F16);
        let mut no_skip = ours.clone();
        no_skip.causal_block_skip = false;
        let with = estimate(&spec, &arch, &ours);
        let without = estimate(&spec, &arch, &no_skip);
        assert!(
            with.tflops > 1.6 * without.tflops,
            "skip {} vs no-skip {}",
            with.tflops,
            without.tflops
        );
    }

    #[test]
    fn paged_io_term_charges_smaller_pages_more() {
        let arch = GpuArch::a100();
        let sched = schedules::ours(&arch, 64, crate::tl::types::DType::F16);
        let base = mha(4096, 64, true);
        let contiguous = estimate(&base, &arch, &sched).seconds;
        let mut prev = contiguous;
        for page in [64usize, 16, 4] {
            let spec = base.with_layout(KvLayout::Paged { page_size: page });
            let t = estimate(&spec, &arch, &sched).seconds;
            assert!(t >= prev, "page {page}: paged must not get cheaper as pages shrink");
            prev = t;
        }
    }

    #[test]
    fn sliding_window_wins_at_long_context_without_reread_halving() {
        let arch = GpuArch::a100();
        let sched = schedules::ours(&arch, 64, crate::tl::types::DType::F16);
        let base = mha(16384, 64, true);
        let win = base.with_layout(KvLayout::Sliding { window: 512 });
        let full = estimate(&base, &arch, &sched);
        let clipped = estimate(&win, &arch, &sched);
        assert!(
            clipped.seconds < full.seconds,
            "a 512-window sweep of a 16k context must beat the full causal sweep"
        );
        assert!(clipped.dram_gb < full.dram_gb);
    }

    #[test]
    fn score_rect_elems_clips_per_pattern() {
        let dense = mha(4096, 64, false);
        let bh = (dense.batch * dense.num_q_heads) as f64;
        assert_eq!(score_rect_elems(&dense), bh * 4096.0 * 4096.0);
        let bs = dense
            .with_pattern(ScorePattern::BlockSparse { block: 64, topk: 16 })
            .unwrap();
        assert_eq!(score_rect_elems(&bs), bh * 4096.0 * 1024.0);
        let wg = mha(4096, 64, true)
            .with_pattern(ScorePattern::WindowGlobal { window: 512, n_global: 64 })
            .unwrap();
        assert_eq!(score_rect_elems(&wg), bh * 4096.0 * 576.0);
    }

    #[test]
    fn sparse_patterns_price_below_dense_at_long_context() {
        let arch = GpuArch::a100();
        let sched = schedules::ours(&arch, 64, crate::tl::types::DType::F16);
        let dense = mha(16384, 64, false);
        let bs = dense
            .with_pattern(ScorePattern::BlockSparse { block: 64, topk: 16 })
            .unwrap();
        let full = estimate(&dense, &arch, &sched);
        let clipped = estimate(&bs, &arch, &sched);
        assert!(
            clipped.seconds < full.seconds / 2.0,
            "16-of-256-tile selection must beat the dense sweep: {} vs {}",
            clipped.seconds,
            full.seconds
        );
        assert!(clipped.dram_gb < full.dram_gb);

        let causal = mha(16384, 64, true);
        let wg = causal
            .with_pattern(ScorePattern::WindowGlobal { window: 512, n_global: 64 })
            .unwrap();
        let full = estimate(&causal, &arch, &sched);
        let clipped = estimate(&wg, &arch, &sched);
        assert!(
            clipped.seconds < full.seconds,
            "a 512-window + 64-global sweep must beat the full causal sweep"
        );
        assert!(clipped.dram_gb < full.dram_gb);
    }

    #[test]
    fn backward_costs_more_wall_clock_than_forward() {
        let arch = GpuArch::a100();
        let sched = schedules::ours(&arch, 64, crate::tl::types::DType::F16);
        for seq in [1024usize, 4096, 16384] {
            let fwd = mha(seq, 64, true);
            let bwd = fwd.with_direction(Direction::Backward);
            let f = estimate(&fwd, &arch, &sched);
            let b = estimate(&bwd, &arch, &sched);
            assert!(
                b.seconds > 1.5 * f.seconds,
                "seq {seq}: backward {} vs forward {}",
                b.seconds,
                f.seconds
            );
            assert!(b.seconds.is_finite() && b.tflops > 0.0);
            assert!(b.dram_gb > f.dram_gb, "backward moves more bytes");
        }
    }

    #[test]
    fn backward_tflops_still_rise_with_sequence_length() {
        let arch = GpuArch::a100();
        let sched = schedules::ours(&arch, 64, crate::tl::types::DType::F16);
        let mut prev = 0.0;
        for seq in [512, 1024, 2048, 4096, 8192, 16384] {
            let est = estimate(
                &mha(seq, 64, true).with_direction(Direction::Backward),
                &arch,
                &sched,
            );
            assert!(est.tflops > prev, "backward TFLOPS must rise: {} at {seq}", est.tflops);
            prev = est.tflops;
        }
    }

    #[test]
    fn identity_calibration_recombines_estimate_exactly() {
        // The decomposed terms must recombine to the exact bits the
        // monolithic formula produced — calibration is a pure overlay.
        let id = Calibration::identity();
        for arch in GpuArch::all() {
            for spec in crate::workload::table1_grid(true) {
                for sched in schedules::baselines(&arch, spec.head_dim, spec.dtype) {
                    let est = estimate(&spec, &arch, &sched);
                    let terms = cost_terms(&spec, &arch, &sched);
                    assert_eq!(est.oom, terms.oom, "{} on {}", sched.name, arch.name);
                    if !est.oom {
                        assert_eq!(
                            est.seconds.to_bits(),
                            terms.seconds_with(&id).to_bits(),
                            "{} on {}: identity recombine drifted",
                            sched.name,
                            arch.name
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn calibration_multipliers_scale_their_component() {
        let arch = GpuArch::a100();
        let sched = schedules::ours(&arch, 64, crate::tl::types::DType::F16);
        let spec = mha(4096, 64, true);
        let base = estimate(&spec, &arch, &sched);
        // Slowing every component 3x slows wall-clock (minus the fixed
        // launch overhead) exactly 3x for the fused max-combine.
        let slow = Calibration { gemm: 3.0, softmax: 3.0, membw: 3.0, samples: 0 };
        let s = estimate_calibrated(&spec, &arch, &sched, &slow);
        let want = (base.seconds - KERNEL_LAUNCH_S) * 3.0 + KERNEL_LAUNCH_S;
        assert!((s.seconds / want - 1.0).abs() < 1e-12, "{} vs {want}", s.seconds);
        // A gemm-only slowdown never *reduces* time, and dram_gb (pure
        // traffic accounting) is untouched by any calibration.
        let gemm_only = Calibration { gemm: 2.0, ..Calibration::identity() };
        let g = estimate_calibrated(&spec, &arch, &sched, &gemm_only);
        assert!(g.seconds >= base.seconds);
        assert_eq!(g.dram_gb.to_bits(), base.dram_gb.to_bits());
    }

    #[test]
    fn estimates_are_finite_and_positive_across_grid() {
        for arch in GpuArch::all() {
            for spec in crate::workload::table1_grid(true) {
                for sched in schedules::baselines(&arch, spec.head_dim, spec.dtype) {
                    let est = estimate(&spec, &arch, &sched);
                    if !est.oom {
                        assert!(est.seconds.is_finite() && est.seconds > 0.0);
                        assert!(est.tflops > 0.0, "{} on {}", sched.name, arch.name);
                    }
                }
            }
        }
    }
}
