//! NSA latency model (Table 9).
//!
//! The paper reports end-to-end latency (seconds) of a naive PyTorch NSA
//! versus the generated blocked kernel on A100/hd128. Both run the same
//! three branches (compression, top-k selection, sliding window); the
//! naive version is dominated by eager-mode per-element overhead in the
//! argsort/gather-heavy selection path, which scales with the full score
//! rectangle. We model both as a per-score-element cost (calibrated at
//! seq=512: 0.84 s naive) — the blocked version fuses the branch updates
//! into one online-softmax pass over gathered blocks, removing ~21% of
//! the per-element work (paper: 1.24-1.33x).

use super::cost::score_rect_elems;
use super::gpu::GpuArch;
use crate::sketch::spec::OpSpec;

/// Calibrated per-score-element costs on A100 (seconds). Other cards
/// scale by bandwidth ratio (the path is overhead/traffic-bound).
const NAIVE_ELEM_COST_A100: f64 = 6.3e-9;
const BLOCKED_ELEM_COST_A100: f64 = 5.0e-9;

/// Table 9 latency: the per-element calibration applied to the shared
/// score-rectangle model ([`score_rect_elems`]) — NSA specs carry a
/// dense rectangle (the eager baseline materializes all of it), and a
/// [`crate::sketch::spec::ScorePattern`]-restricted spec is priced on
/// its clipped rectangle by the same formula.
pub fn nsa_latency_s(spec: &OpSpec, arch: &GpuArch, blocked: bool) -> f64 {
    let elems = score_rect_elems(spec);
    let a100_bw = 2039.0;
    let scale = a100_bw / arch.mem_bw_gbs;
    let cost = if blocked { BLOCKED_ELEM_COST_A100 } else { NAIVE_ELEM_COST_A100 };
    elems * cost * scale
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_anchors() {
        let arch = GpuArch::a100();
        // Paper Table 9: naive 0.84 s @512, 26.29 s @16k; ours 0.67/21.27.
        let s512 = OpSpec::nsa(512);
        let s16k = OpSpec::nsa(16384);
        let naive512 = nsa_latency_s(&s512, &arch, false);
        let naive16k = nsa_latency_s(&s16k, &arch, false);
        let ours512 = nsa_latency_s(&s512, &arch, true);
        let ours16k = nsa_latency_s(&s16k, &arch, true);
        assert!((naive512 - 0.84).abs() / 0.84 < 0.1, "{naive512}");
        assert!((naive16k - 26.29).abs() / 26.29 < 0.1, "{naive16k}");
        // Speedup in the paper's 1.24-1.33x band.
        assert!((1.15..1.40).contains(&(naive512 / ours512)));
        assert!((1.15..1.40).contains(&(naive16k / ours16k)));
    }

    #[test]
    fn latency_routes_through_the_pattern_clipped_rectangle() {
        use crate::sketch::spec::{AttnVariant, ScorePattern};
        let arch = GpuArch::a100();
        let dense = OpSpec::benchmark(AttnVariant::Mha, 4096, 128, false);
        let bs = dense
            .with_pattern(ScorePattern::BlockSparse { block: 64, topk: 16 })
            .unwrap();
        let full = nsa_latency_s(&dense, &arch, true);
        let clipped = nsa_latency_s(&bs, &arch, true);
        // 16 tiles × 64 rows of 4096 keys -> exactly a 4x smaller rectangle.
        assert!((full / clipped - 4.0).abs() < 1e-9, "{}", full / clipped);
    }

    #[test]
    fn latency_scales_linearly_in_seq_at_fixed_tokens() {
        // total tokens fixed -> b*s^2 = 16k*s -> latency linear in s.
        let arch = GpuArch::a100();
        let l1 = nsa_latency_s(&OpSpec::nsa(1024), &arch, false);
        let l2 = nsa_latency_s(&OpSpec::nsa(2048), &arch, false);
        assert!((l2 / l1 - 2.0).abs() < 0.05);
    }
}
