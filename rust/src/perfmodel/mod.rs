//! Analytical GPU performance model.
//!
//! Substitutes for the physical A100 / RTX8000 / T4 / L40S testbeds (see
//! DESIGN.md §2): GPU descriptors ([`gpu`]), the schedule cost model
//! ([`cost`]), per-implementation schedule presets ([`schedules`]), the
//! NSA latency model ([`nsa`]), and the self-calibration loop
//! ([`calibrate`]) that fits the cost model's three time components to
//! observed runtimes from the tuning cache. The table renderers in
//! [`crate::report`] drive this model to regenerate every table and
//! figure of the paper's evaluation.

pub mod calibrate;
pub mod cost;
pub mod gpu;
pub mod nsa;
pub mod schedules;

pub use calibrate::{Calibration, CalibrationSet};
pub use cost::{estimate, estimate_calibrated, Estimate, Schedule};
pub use gpu::GpuArch;
