//! Analytical GPU performance model.
//!
//! Substitutes for the physical A100 / RTX8000 / T4 / L40S testbeds (see
//! DESIGN.md §2): GPU descriptors ([`gpu`]), the schedule cost model
//! ([`cost`]), per-implementation schedule presets ([`schedules`]) and
//! the NSA latency model ([`nsa`]). The table renderers in
//! [`crate::report`] drive this model to regenerate every table and
//! figure of the paper's evaluation.

pub mod cost;
pub mod gpu;
pub mod nsa;
pub mod schedules;

pub use cost::{estimate, Estimate, Schedule};
pub use gpu::GpuArch;
