//! Self-calibrating cost model: fit the analytical model's three time
//! components to *observed* runtimes accumulated in the tuning cache.
//!
//! The paper's search loop scores candidates "against the hardware"
//! (§3.2); our stand-in hardware is the analytical model
//! ([`super::cost`]), whose rate coefficients were hand-anchored at the
//! paper's published measurements. Serving and benching accumulate
//! *measured* latencies per schedule variant
//! ([`crate::autotune::cache::TuneCache::observe`]) — evidence the
//! model can learn from. This module closes that loop:
//!
//! * [`Calibration`] — three multiplicative corrections (`gemm`,
//!   `softmax`, `membw`) applied to the model's decomposed time
//!   components by [`super::cost::estimate_calibrated`]. Values > 1
//!   mean the target runs that component slower than modeled. The
//!   identity calibration reproduces the uncalibrated model exactly.
//! * [`fit`] — weighted least squares over [`FitSample`]s (decomposed
//!   model features vs observed seconds), with a single-scale
//!   geometric-mean fallback and the identity as a floor, so the fitted
//!   calibration's [`disagreement`] is **never worse** than before.
//! * [`CalibrationSet`] — per-architecture calibrations persisted in a
//!   line-oriented text file beside the tuning cache (format documented
//!   on [`CalibrationSet::parse`] and in `autotune::cache`).
//!
//! The observed entries in this repo come from the host CPU engine (the
//! no-GPU stand-in for on-device runs), so fitted multipliers are far
//! from 1 — they absorb the CPU-vs-GPU scale along with the shape of
//! the disagreement. That is by design: calibration aligns the model
//! with whatever hardware actually produced the observations.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use super::cost::{self, Schedule};
use super::gpu::GpuArch;
use crate::sketch::spec::OpSpec;

/// Fitted multipliers are clamped into this range: wide enough to
/// absorb host-interpreter observations standing in for on-device runs
/// (three to six decimal orders off GPU scale), tight enough that a
/// degenerate fit can never produce a zero or infinite rate.
const MIN_MULT: f64 = 1e-3;
/// Upper clamp for fitted multipliers (see [`MIN_MULT`]).
const MAX_MULT: f64 = 1e9;

/// Multiplicative corrections to the cost model's three decomposed time
/// components ([`cost::CostTerms`]). Applied by
/// [`cost::estimate_calibrated`]; fitted by [`fit`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Calibration {
    /// Multiplier on GEMM / Tensor-Core compute time.
    pub gemm: f64,
    /// Multiplier on exposed softmax / pointwise CUDA-core time.
    pub softmax: f64,
    /// Multiplier on DRAM-traffic time (an inverse achieved-bandwidth
    /// correction).
    pub membw: f64,
    /// Observed entries the fit consumed (0 for the identity and for
    /// intermediate fit candidates).
    pub samples: usize,
}

impl Calibration {
    /// The no-op calibration: [`cost::estimate_calibrated`] with it
    /// reproduces [`cost::estimate`] bit-for-bit.
    pub const fn identity() -> Self {
        Calibration { gemm: 1.0, softmax: 1.0, membw: 1.0, samples: 0 }
    }

    /// Exactly the identity multipliers (sample count ignored)?
    pub fn is_identity(&self) -> bool {
        self.gemm == 1.0 && self.softmax == 1.0 && self.membw == 1.0
    }
}

impl Default for Calibration {
    fn default() -> Self {
        Calibration::identity()
    }
}

impl std::fmt::Display for Calibration {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "gemm={:.3e} softmax={:.3e} membw={:.3e} ({} samples)",
            self.gemm, self.softmax, self.membw, self.samples
        )
    }
}

/// One observed-vs-modeled pair: the model's identity-calibration time
/// components for the schedule that was measured, plus the measurement.
#[derive(Debug, Clone, Copy)]
pub struct FitSample {
    /// Identity-calibration GEMM seconds
    /// ([`cost::CostTerms::components`]).
    pub gemm: f64,
    /// Identity-calibration exposed-softmax seconds.
    pub softmax: f64,
    /// Identity-calibration DRAM-traffic seconds.
    pub mem: f64,
    /// Uncalibrated launch-overhead seconds.
    pub overhead: f64,
    /// Fused combine (`max(compute, mem)`) vs unfused sum.
    pub fused: bool,
    /// Measured wall-clock seconds.
    pub observed: f64,
}

impl FitSample {
    /// Decompose `(spec, arch, sched)` through the cost model and pair
    /// it with a measured runtime. `None` when the measurement is
    /// non-positive/non-finite or the model declares the cell OOM.
    pub fn new(
        spec: &OpSpec,
        arch: &GpuArch,
        sched: &Schedule,
        observed_seconds: f64,
    ) -> Option<FitSample> {
        if !observed_seconds.is_finite() || observed_seconds <= 0.0 {
            return None;
        }
        let t = cost::cost_terms(spec, arch, sched);
        if t.oom {
            return None;
        }
        let (gemm, softmax, mem) = t.components();
        Some(FitSample {
            gemm,
            softmax,
            mem,
            overhead: t.overhead,
            fused: t.fused,
            observed: observed_seconds,
        })
    }

    /// Modeled seconds for this sample under `cal` — the same combine
    /// rule as [`cost::CostTerms::seconds_with`], so [`disagreement`]
    /// scores exactly what [`cost::estimate_calibrated`] would predict.
    pub fn modeled(&self, cal: &Calibration) -> f64 {
        let compute = self.gemm * cal.gemm + self.softmax * cal.softmax;
        let mem = self.mem * cal.membw;
        if self.fused {
            compute.max(mem) + self.overhead
        } else {
            mem + compute + self.overhead
        }
    }
}

/// RMS over samples of `ln(modeled / observed)` — the
/// observed-vs-modeled disagreement score `tlc tune --report` prints
/// (0 = the model predicts every observation exactly; each unit is one
/// e-fold of average misprediction). Empty sample sets score 0.
pub fn disagreement(samples: &[FitSample], cal: &Calibration) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0;
    for s in samples {
        let m = s.modeled(cal).max(1e-300);
        let r = (m / s.observed).ln();
        acc += r * r;
    }
    (acc / samples.len() as f64).sqrt()
}

/// Fit a [`Calibration`] to observed samples.
///
/// Three candidates are scored and the lowest [`disagreement`] wins:
/// the full three-term least-squares fit, a single-scale fit (one
/// geometric-mean ratio applied to all three components — robust when
/// the samples cannot separate the components), and the identity. The
/// identity floor guarantees the fit never *increases* disagreement.
pub fn fit(samples: &[FitSample]) -> Calibration {
    let mut best = Calibration::identity();
    if samples.is_empty() {
        return best;
    }
    let mut best_d = disagreement(samples, &best);
    for cand in [fit_scale(samples), fit_three_term(samples)].into_iter().flatten() {
        let d = disagreement(samples, &cand);
        if d < best_d {
            best = cand;
            best_d = d;
        }
    }
    Calibration { samples: samples.len(), ..best }
}

/// Single-scale fit: the geometric mean of `observed / modeled` applied
/// to all three components. For fused samples this scales the whole
/// `max(compute, mem)` uniformly, so it exactly absorbs any constant
/// rate offset (e.g. a CPU host standing in for the GPU).
fn fit_scale(samples: &[FitSample]) -> Option<Calibration> {
    let id = Calibration::identity();
    let mut acc = 0.0;
    let mut n = 0usize;
    for s in samples {
        let m = s.modeled(&id);
        if m.is_finite() && m > 0.0 {
            acc += (s.observed / m).ln();
            n += 1;
        }
    }
    if n == 0 {
        return None;
    }
    let r = (acc / n as f64).exp();
    if !r.is_finite() || r <= 0.0 {
        return None;
    }
    let r = r.clamp(MIN_MULT, MAX_MULT);
    Some(Calibration { gemm: r, softmax: r, membw: r, samples: 0 })
}

/// Full three-term fit: minimize the sum of squared *relative*
/// residuals `(pred/observed - 1)^2` over the three multipliers.
///
/// Fused samples predict through a `max(compute, mem)`, which a linear
/// solver cannot represent directly, so the fit iterates: each round
/// assigns every fused sample to the side of the `max` that binds
/// under the current iterate and solves the resulting linear problem
/// (an EM-style active-branch refinement, warm-started from the
/// single-scale fit). A small ridge term biased toward the *identity*
/// keeps components no sample exercises at multiplier 1 instead of
/// letting them drift to 0 or blow up.
fn fit_three_term(samples: &[FitSample]) -> Option<Calibration> {
    let mut cal = fit_scale(samples).unwrap_or_else(Calibration::identity);
    for _ in 0..8 {
        let mut xtx = [[0.0f64; 3]; 3];
        let mut xty = [0.0f64; 3];
        for s in samples {
            let inv = 1.0 / s.observed;
            let (g, sm, mm) = if s.fused {
                let compute = s.gemm * cal.gemm + s.softmax * cal.softmax;
                if compute >= s.mem * cal.membw {
                    (s.gemm, s.softmax, 0.0)
                } else {
                    (0.0, 0.0, s.mem)
                }
            } else {
                (s.gemm, s.softmax, s.mem)
            };
            let x = [g * inv, sm * inv, mm * inv];
            let y = 1.0 - s.overhead * inv;
            for i in 0..3 {
                for j in 0..3 {
                    xtx[i][j] += x[i] * x[j];
                }
                xty[i] += x[i] * y;
            }
        }
        let lambda = 1e-6 * (xtx[0][0] + xtx[1][1] + xtx[2][2]).max(1e-12) / 3.0;
        for i in 0..3 {
            xtx[i][i] += lambda;
            xty[i] += lambda; // ridge toward the identity (c = 1), not 0
        }
        let sol = solve3(xtx, xty)?;
        let next = Calibration {
            gemm: sol[0].clamp(MIN_MULT, MAX_MULT),
            softmax: sol[1].clamp(MIN_MULT, MAX_MULT),
            membw: sol[2].clamp(MIN_MULT, MAX_MULT),
            samples: 0,
        };
        let moved = (next.gemm / cal.gemm - 1.0).abs()
            + (next.softmax / cal.softmax - 1.0).abs()
            + (next.membw / cal.membw - 1.0).abs();
        cal = next;
        if moved < 1e-9 {
            break;
        }
    }
    (cal.gemm.is_finite() && cal.softmax.is_finite() && cal.membw.is_finite()).then_some(cal)
}

/// Solve the 3x3 system `a x = b` by Gauss-Jordan elimination with
/// partial pivoting; `None` when singular.
fn solve3(a: [[f64; 3]; 3], b: [f64; 3]) -> Option<[f64; 3]> {
    let mut m = [[0.0f64; 4]; 3];
    for i in 0..3 {
        m[i][..3].copy_from_slice(&a[i]);
        m[i][3] = b[i];
    }
    for col in 0..3 {
        let mut piv = col;
        for r in col + 1..3 {
            if m[r][col].abs() > m[piv][col].abs() {
                piv = r;
            }
        }
        if m[piv][col].abs() < 1e-300 {
            return None;
        }
        m.swap(col, piv);
        for r in 0..3 {
            if r == col {
                continue;
            }
            let f = m[r][col] / m[col][col];
            for c in col..4 {
                m[r][c] -= f * m[col][c];
            }
        }
    }
    Some([m[0][3] / m[0][0], m[1][3] / m[1][1], m[2][3] / m[2][2]])
}

/// Per-architecture calibrations, persisted in a text file beside the
/// tuning cache (see [`CalibrationSet::path_beside`]). Architectures
/// without a fitted entry read as the identity, so a missing or partial
/// file degrades to the uncalibrated model.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CalibrationSet {
    by_arch: BTreeMap<String, Calibration>,
}

impl CalibrationSet {
    /// An empty set (every arch reads as identity).
    pub fn new() -> Self {
        Self::default()
    }

    /// The calibration fitted for `arch_name`, or the identity.
    pub fn get(&self, arch_name: &str) -> Calibration {
        self.by_arch.get(arch_name).copied().unwrap_or_else(Calibration::identity)
    }

    /// Record `cal` for `arch_name`, replacing any previous fit.
    pub fn set(&mut self, arch_name: &str, cal: Calibration) {
        self.by_arch.insert(arch_name.to_string(), cal);
    }

    /// Number of architectures with a fitted entry.
    pub fn len(&self) -> usize {
        self.by_arch.len()
    }

    /// No architecture has a fitted entry?
    pub fn is_empty(&self) -> bool {
        self.by_arch.is_empty()
    }

    /// Where the calibration file lives for a given tune-cache path:
    /// a sibling named `<cache stem>.calib.txt` (so the default
    /// `tune_cache.txt` pairs with `tune_cache.calib.txt`, and an
    /// artifacts-dir `tune.txt` with `tune.calib.txt`).
    pub fn path_beside(cache_path: &Path) -> PathBuf {
        let stem = cache_path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("tune_cache");
        cache_path.with_file_name(format!("{stem}.calib.txt"))
    }

    /// Parse the text format:
    ///
    /// ```text
    /// # qimeng calibration v1
    /// calib gemm=<f64> softmax=<f64> membw=<f64> samples=<n> arch=<name>
    /// ```
    ///
    /// One line per architecture; `arch=` is last and takes the rest of
    /// the line. `#` comments and blank lines are skipped. Non-finite
    /// or non-positive multipliers are rejected — a poisoned file must
    /// not silently corrupt every search ranking downstream.
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut set = CalibrationSet::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let body = line
                .strip_prefix("calib ")
                .ok_or_else(|| format!("calibration line {}: expected `calib`", lineno + 1))?;
            let (head, arch) = body.split_once(" arch=").ok_or_else(|| {
                format!("calibration line {}: missing arch= field", lineno + 1)
            })?;
            let arch = arch.trim();
            if arch.is_empty() {
                return Err(format!("calibration line {}: empty arch name", lineno + 1));
            }
            let mut fields: BTreeMap<&str, &str> = BTreeMap::new();
            for kv in head.split_whitespace() {
                let (k, v) = kv.split_once('=').ok_or_else(|| {
                    format!("calibration line {}: bad field `{kv}`", lineno + 1)
                })?;
                fields.insert(k, v);
            }
            let mult = |name: &str| -> Result<f64, String> {
                let raw = fields
                    .get(name)
                    .ok_or_else(|| format!("calibration arch {arch}: missing {name}="))?;
                let v: f64 = raw
                    .parse()
                    .map_err(|_| format!("calibration arch {arch}: {name} not a number"))?;
                if !v.is_finite() || v <= 0.0 {
                    return Err(format!(
                        "calibration arch {arch}: {name} must be finite and positive, got {v}"
                    ));
                }
                Ok(v)
            };
            let cal = Calibration {
                gemm: mult("gemm")?,
                softmax: mult("softmax")?,
                membw: mult("membw")?,
                samples: fields
                    .get("samples")
                    .and_then(|s| s.parse().ok())
                    .unwrap_or(0),
            };
            set.by_arch.insert(arch.to_string(), cal);
        }
        Ok(set)
    }

    /// Serialize back to the text format (stable BTreeMap order; `{}`
    /// f64 formatting is Rust's shortest-roundtrip form, so a
    /// parse-render cycle is a fixed point).
    pub fn render(&self) -> String {
        let mut out = String::from("# qimeng calibration v1\n");
        for (arch, c) in &self.by_arch {
            out.push_str(&format!(
                "calib gemm={} softmax={} membw={} samples={} arch={arch}\n",
                c.gemm, c.softmax, c.membw, c.samples
            ));
        }
        out
    }

    /// Load from disk; a missing file is an empty set (uncalibrated).
    pub fn load(path: &Path) -> Result<Self, String> {
        match std::fs::read_to_string(path) {
            Ok(text) => {
                Self::parse(&text).map_err(|e| format!("parsing {}: {e}", path.display()))
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(CalibrationSet::new()),
            Err(e) => Err(format!("reading {}: {e}", path.display())),
        }
    }

    /// Write to disk (parent directories created as needed).
    pub fn save(&self, path: &Path) -> Result<(), String> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .map_err(|e| format!("creating {}: {e}", parent.display()))?;
            }
        }
        std::fs::write(path, self.render()).map_err(|e| format!("writing {}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::schedules;
    use crate::sketch::spec::AttnVariant;

    /// A sample set with enough shape diversity to separate the three
    /// components: head-dims 64/128 and causal on/off vary the
    /// gemm:softmax ratio; the unfused schedule exercises the linear
    /// (sum) combine; long-context cells lean on the memory term.
    fn probe_samples(mut observe: impl FnMut(&FitSample) -> f64) -> Vec<FitSample> {
        let arch = GpuArch::a100();
        let mut out = Vec::new();
        for (seq, hd, causal) in [
            (1024usize, 64usize, true),
            (2048, 64, false),
            (4096, 64, true),
            (4096, 128, false),
            (8192, 128, true),
            (16384, 64, true),
        ] {
            let spec = OpSpec::benchmark(AttnVariant::Mha, seq, hd, causal);
            for (bm, bn) in [(128usize, 64usize), (64, 64), (64, 32)] {
                let mut sched = schedules::ours(&arch, hd, spec.dtype);
                sched.bm = bm;
                sched.bn = bn;
                if let Some(mut s) = FitSample::new(&spec, &arch, &sched, 1.0) {
                    s.observed = observe(&s);
                    out.push(s);
                }
            }
            let naive = schedules::torch_naive();
            if let Some(mut s) = FitSample::new(&spec, &arch, &naive, 1.0) {
                s.observed = observe(&s);
                out.push(s);
            }
        }
        assert!(out.len() >= 12, "probe set unexpectedly small: {}", out.len());
        out
    }

    #[test]
    fn fit_recovers_known_multipliers_from_synthetic_observations() {
        // Synthesize observations from a known ground-truth calibration;
        // the fit must recover it (satellite: the self-calibration loop
        // is sound, not just monotone).
        let truth = Calibration { gemm: 3.0, softmax: 1.5, membw: 7.0, samples: 0 };
        let samples = probe_samples(|s| s.modeled(&truth));
        let cal = fit(&samples);
        assert_eq!(cal.samples, samples.len());
        for (got, want, name) in [
            (cal.gemm, truth.gemm, "gemm"),
            (cal.softmax, truth.softmax, "softmax"),
            (cal.membw, truth.membw, "membw"),
        ] {
            assert!(
                (got / want - 1.0).abs() < 0.1,
                "{name}: fitted {got} vs truth {want}"
            );
        }
        let post = disagreement(&samples, &cal);
        assert!(post < 0.05, "residual disagreement {post}");
        assert!(post < disagreement(&samples, &Calibration::identity()));
    }

    #[test]
    fn fit_never_increases_disagreement() {
        // Observations = modeled x a large constant plus deterministic
        // per-sample jitter (the host-CPU-standing-in-for-GPU regime).
        let mut i = 0u64;
        let samples = probe_samples(|s| {
            i = i.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let jitter = 1.0 + 0.3 * ((i >> 33) as f64 / (1u64 << 31) as f64 - 0.5);
            s.modeled(&Calibration::identity()) * 25_000.0 * jitter
        });
        let pre = disagreement(&samples, &Calibration::identity());
        let cal = fit(&samples);
        let post = disagreement(&samples, &cal);
        assert!(post <= pre, "fit must not increase disagreement: {pre} -> {post}");
        // The scale gap is 25000x: calibration must close most of it.
        assert!(post < 0.5 * pre, "fit barely moved: {pre} -> {post}");
        // And the fitted multipliers absorb the host-vs-model scale.
        assert!(cal.gemm > 100.0 || cal.membw > 100.0);
    }

    #[test]
    fn identity_fit_on_empty_and_perfect_samples() {
        assert!(fit(&[]).is_identity());
        let samples = probe_samples(|s| s.modeled(&Calibration::identity()));
        let cal = fit(&samples);
        // Perfect observations: disagreement is already ~0; whatever
        // candidate wins must keep it there.
        assert!(disagreement(&samples, &cal) < 1e-6);
    }

    #[test]
    fn calibration_set_roundtrips_through_text() {
        let mut set = CalibrationSet::new();
        set.set("A100", Calibration { gemm: 3.25, softmax: 1.5, membw: 27000.0, samples: 42 });
        set.set("T4", Calibration { gemm: 0.5, softmax: 2.0, membw: 1.0, samples: 7 });
        let parsed = CalibrationSet::parse(&set.render()).unwrap();
        assert_eq!(parsed, set);
        // Render is a fixed point after one parse.
        assert_eq!(parsed.render(), set.render());
        // Unfitted arches read as identity.
        assert!(parsed.get("L40S").is_identity());
        assert_eq!(parsed.get("A100").samples, 42);
    }

    #[test]
    fn calibration_set_parse_rejects_garbage() {
        assert!(CalibrationSet::parse("# comment only\n\n").unwrap().is_empty());
        assert!(CalibrationSet::parse("notcalib gemm=1 arch=A100").is_err());
        assert!(CalibrationSet::parse("calib gemm=1 softmax=1 membw=1").is_err());
        assert!(CalibrationSet::parse("calib gemm=nan softmax=1 membw=1 arch=A100").is_err());
        assert!(CalibrationSet::parse("calib gemm=-2 softmax=1 membw=1 arch=A100").is_err());
        assert!(CalibrationSet::parse("calib softmax=1 membw=1 arch=A100").is_err());
    }

    #[test]
    fn calibration_file_sits_beside_the_cache() {
        assert_eq!(
            CalibrationSet::path_beside(Path::new("tune_cache.txt")),
            PathBuf::from("tune_cache.calib.txt")
        );
        assert_eq!(
            CalibrationSet::path_beside(Path::new("artifacts/tune.txt")),
            PathBuf::from("artifacts/tune.calib.txt")
        );
    }

    #[test]
    fn calibration_set_save_load_roundtrip() {
        let dir = std::env::temp_dir().join("qimeng_calibration_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tune.calib.txt");
        let mut set = CalibrationSet::new();
        set.set("A100", Calibration { gemm: 2.0, softmax: 3.0, membw: 4.0, samples: 9 });
        set.save(&path).unwrap();
        let loaded = CalibrationSet::load(&path).unwrap();
        assert_eq!(loaded, set);
        // Missing file loads as the empty (uncalibrated) set.
        assert!(CalibrationSet::load(Path::new("/nonexistent/x.calib.txt"))
            .unwrap()
            .is_empty());
    }
}
