//! End-to-end generation pipeline: the paper's Figure-3 workflow as one
//! callable unit.
//!
//! ```text
//! OpSpec ──sketch──▶ TL Sketch ──reason──▶ TL Code ──verify──▶ backend ──▶ source
//! ```
//!
//! Every run records per-stage wall-clock so the Table-4 development-cost
//! comparison ("months → minutes"; here milliseconds) is measured, not
//! asserted. Each stage runs under an [`crate::obs`] span
//! (`pipeline.sketch` … `pipeline.translate`); the span's
//! [`crate::obs::SpanGuard::finish`] return value is the stage timer, so
//! [`Timings`] stays populated whether or not tracing is enabled.

use std::time::Duration;

use crate::obs;

use crate::perfmodel::gpu::GpuArch;
use crate::reasoner::profiles::LlmProfile;
use crate::reasoner::{self, Reasoned};
use crate::sketch::spec::Direction;
use crate::sketch::{self, spec::OpSpec, GradTarget};
use crate::tl::ast::TlProgram;
use crate::translate::{cute::CuteBackend, pallas::PallasBackend, Backend};
use crate::verify::{self, VerifyReport};

/// Which backend to translate to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Target {
    Pallas,
    Cute,
}

impl Target {
    /// Parse the `--backend` CLI flag (shared by the `tlc` subcommands).
    pub fn from_cli(args: &crate::util::cli::Args) -> Result<Self, String> {
        match args.get_or("backend", "pallas") {
            "pallas" => Ok(Target::Pallas),
            "cute" => Ok(Target::Cute),
            other => Err(format!("unknown --backend `{other}`")),
        }
    }
}

#[derive(Debug)]
pub struct PipelineResult {
    pub sketch: TlProgram,
    /// The primary reasoned program: the forward kernel, or the dQ
    /// program of a backward run (its q-block sweep mirrors the forward).
    pub reasoned: Reasoned,
    pub verify: VerifyReport,
    /// Emitted backend source (None if verification failed or the profile
    /// cannot translate — the GPT-4o row of Table 3). A backward run
    /// emits the whole bundle as one module.
    pub source: Option<String>,
    pub timings: Timings,
    /// Autotuner outcome when the run went through [`run_tuned`].
    pub tune: Option<crate::autotune::TuneResult>,
    /// The full backward bundle (dQ, dK, dV), each verified; empty for
    /// forward runs.
    pub backward: Vec<(GradTarget, Reasoned)>,
}

#[derive(Debug, Default, Clone)]
pub struct Timings {
    /// Schedule search (zero unless the run went through [`run_tuned`];
    /// cache hits keep it near-zero on repeat runs).
    pub search: Duration,
    pub sketch: Duration,
    pub reason: Duration,
    pub verify: Duration,
    pub translate: Duration,
}

impl Timings {
    pub fn total(&self) -> Duration {
        self.search + self.sketch + self.reason + self.verify + self.translate
    }
}

#[derive(Debug)]
pub enum PipelineError {
    /// Verification rejected the TL Code (diagnostics inside).
    VerifyFailed(VerifyReport),
    /// The selected profile cannot run stage-2 translation (GPT-4o).
    CannotTranslate(&'static str),
    Translate(crate::translate::TranslateError),
}

impl std::fmt::Display for PipelineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PipelineError::VerifyFailed(r) => {
                write!(f, "verification failed:")?;
                for d in &r.diagnostics {
                    write!(f, "\n  {d}")?;
                }
                if let Some(diff) = r.max_abs_diff {
                    write!(f, "\n  numeric probe max|diff| = {diff:e}")?;
                }
                Ok(())
            }
            PipelineError::CannotTranslate(name) => {
                write!(f, "profile `{name}` cannot translate TL to backend code (Table 3)")
            }
            PipelineError::Translate(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for PipelineError {}

/// Run the full pipeline. Returns Ok even when verification fails only if
/// `allow_unverified` (used by the ablation driver to show the rejected
/// code); otherwise failures are errors.
pub fn run(
    spec: &OpSpec,
    arch: &GpuArch,
    profile: &LlmProfile,
    target: Target,
) -> Result<PipelineResult, PipelineError> {
    run_inner(spec, arch, profile, target, None)
}

/// Run the pipeline with the schedule chosen by the autotuner instead of
/// the profile's tiling strategy. The search (or cache hit) time is
/// recorded in [`Timings::search`], and the winning candidate travels in
/// [`PipelineResult::tune`].
pub fn run_tuned(
    spec: &OpSpec,
    arch: &GpuArch,
    profile: &LlmProfile,
    target: Target,
    tuner: &mut crate::autotune::Autotuner,
) -> Result<PipelineResult, PipelineError> {
    let sp = obs::span_cat("pipeline.search", "pipeline");
    let tune = tuner.tune(spec, arch, target);
    let search = sp.finish();
    run_inner(spec, arch, profile, target, Some((tune, search)))
}

fn run_inner(
    spec: &OpSpec,
    arch: &GpuArch,
    profile: &LlmProfile,
    target: Target,
    tuned: Option<(crate::autotune::TuneResult, Duration)>,
) -> Result<PipelineResult, PipelineError> {
    let backward = spec.direction == Direction::Backward;
    let sp = obs::span_cat("pipeline.sketch", "pipeline");
    let sketch = sketch::generate_sketch(spec);
    let bwd_sketches =
        if backward { sketch::backward_sketches(spec) } else { Vec::new() };
    let t_sketch = sp.finish();

    let sp = obs::span_cat("pipeline.reason", "pipeline");
    let (tune, t_search) = match tuned {
        Some((tune, search)) => (Some(tune), search),
        None => (None, Duration::ZERO),
    };
    let reason_one = |sk: &TlProgram| -> Reasoned {
        match &tune {
            Some(t) => {
                let tiling = crate::autotune::space::tiling_of(&t.candidate, spec, arch);
                reasoner::reason_with_tiling(sk, spec, profile, tiling)
            }
            None => reasoner::reason(sk, spec, arch, profile),
        }
    };
    let bwd_parts: Vec<(GradTarget, Reasoned)> =
        bwd_sketches.iter().map(|(g, sk)| (*g, reason_one(sk))).collect();
    // The primary program of a backward run is its dQ part (already
    // reasoned above); forward runs reason the single sketch.
    let reasoned = bwd_parts
        .iter()
        .find(|(g, _)| *g == GradTarget::DQ)
        .map(|(_, r)| r.clone())
        .unwrap_or_else(|| reason_one(&sketch));
    let t_reason = sp.finish();

    // Verify: the forward program, or every program of the backward
    // bundle (the report kept is the worst-diff one).
    let sp = obs::span_cat("pipeline.verify", "pipeline");
    let mut report = verify::verify_program(&reasoned.program, spec.causal, 0xC0FFEE);
    for (g, r) in &bwd_parts {
        if *g == GradTarget::DQ {
            continue; // same program as `reasoned`, already verified
        }
        if !report.passed {
            break;
        }
        let part_report = verify::verify_program(&r.program, spec.causal, 0xC0FFEE);
        if !part_report.passed
            || part_report.max_abs_diff.unwrap_or(0.0) > report.max_abs_diff.unwrap_or(0.0)
        {
            report = part_report;
        }
    }
    let t_verify = sp.finish();

    if !report.passed {
        return Err(PipelineError::VerifyFailed(report));
    }
    if !profile.can_translate {
        return Err(PipelineError::CannotTranslate(profile.name));
    }

    let sp = obs::span_cat("pipeline.translate", "pipeline");
    let backend: &dyn Backend = match target {
        Target::Pallas => &PallasBackend,
        Target::Cute => &CuteBackend,
    };
    let source = if backward {
        backend.emit_backward(&bwd_parts, spec, arch).map_err(PipelineError::Translate)?
    } else {
        backend.emit(&reasoned, spec, arch).map_err(PipelineError::Translate)?
    };
    let t_translate = sp.finish();

    Ok(PipelineResult {
        sketch,
        reasoned,
        verify: report,
        source: Some(source),
        timings: Timings {
            search: t_search,
            sketch: t_sketch,
            reason: t_reason,
            verify: t_verify,
            translate: t_translate,
        },
        tune,
        backward: bwd_parts,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reasoner::profiles::FailureMode;
    use crate::sketch::spec::AttnVariant;

    #[test]
    fn full_pipeline_produces_source() {
        let spec = OpSpec::benchmark(AttnVariant::Mha, 2048, 64, true);
        let r = run(&spec, &GpuArch::a100(), &LlmProfile::deepseek_v3(), Target::Pallas)
            .expect("pipeline");
        assert!(r.source.unwrap().contains("pallas_call"));
        assert!(r.verify.passed);
    }

    #[test]
    fn pipeline_blocks_unverified_code() {
        let spec = OpSpec::benchmark(AttnVariant::Mha, 2048, 64, true);
        let p = LlmProfile::single_stage(
            LlmProfile::deepseek_v3(),
            FailureMode::ReshapeOmission,
        );
        match run(&spec, &GpuArch::a100(), &p, Target::Pallas) {
            Err(PipelineError::VerifyFailed(r)) => assert!(!r.diagnostics.is_empty()),
            other => panic!("expected VerifyFailed, got {other:?}"),
        }
    }

    #[test]
    fn gpt4o_blocked_at_translation() {
        let spec = OpSpec::benchmark(AttnVariant::Mha, 2048, 64, true);
        match run(&spec, &GpuArch::a100(), &LlmProfile::gpt4o(), Target::Pallas) {
            Err(PipelineError::CannotTranslate(name)) => assert_eq!(name, "GPT-4o"),
            other => panic!("expected CannotTranslate, got {other:?}"),
        }
    }

    #[test]
    fn tuned_pipeline_verifies_and_hits_cache_on_rerun() {
        let spec = OpSpec::benchmark(AttnVariant::Mha, 2048, 64, true);
        let arch = GpuArch::a100();
        let mut tuner = crate::autotune::Autotuner::in_memory();

        let r = run_tuned(&spec, &arch, &LlmProfile::deepseek_v3(), Target::Pallas, &mut tuner)
            .expect("tuned pipeline");
        assert!(r.verify.passed, "autotuned schedule must still verify");
        let tune = r.tune.as_ref().expect("tune outcome recorded");
        assert!(!tune.cached);
        assert_eq!(r.reasoned.tiling.bm, tune.candidate.bm, "searched BM must reach the TL code");
        assert_eq!(r.reasoned.tiling.bn, tune.candidate.bn);

        let r2 = run_tuned(&spec, &arch, &LlmProfile::deepseek_v3(), Target::Pallas, &mut tuner)
            .expect("tuned pipeline rerun");
        assert!(r2.tune.unwrap().cached, "second run must hit the tuning cache");
        assert_eq!(tuner.cache().hits(), 1);
    }

    #[test]
    fn untuned_run_records_no_search_time() {
        let spec = OpSpec::benchmark(AttnVariant::Mha, 2048, 64, true);
        let r = run(&spec, &GpuArch::a100(), &LlmProfile::deepseek_v3(), Target::Pallas)
            .expect("pipeline");
        assert!(r.tune.is_none());
        assert_eq!(r.timings.search, Duration::ZERO);
    }

    #[test]
    fn backward_pipeline_produces_vjp_module() {
        let spec = OpSpec::benchmark(AttnVariant::Mha, 2048, 64, true)
            .with_direction(Direction::Backward);
        let r = run(&spec, &GpuArch::a100(), &LlmProfile::deepseek_v3(), Target::Pallas)
            .expect("backward pipeline");
        assert!(r.verify.passed);
        assert_eq!(r.backward.len(), 3, "the bundle carries dQ, dK and dV");
        assert!(r.reasoned.program.name.ends_with("_bwd_dq"));
        let src = r.source.unwrap();
        assert!(src.contains("def attention_backward("), "{src}");
        assert!(src.contains("_kernel_dq"));
        assert!(src.contains("_kernel_dk"));
        assert!(src.contains("_kernel_dv"));
    }

    #[test]
    fn backward_pipeline_cute_renders_kernels() {
        let spec = OpSpec::benchmark(AttnVariant::Mha, 2048, 64, true)
            .with_direction(Direction::Backward);
        let r = run(&spec, &GpuArch::a100(), &LlmProfile::deepseek_v3(), Target::Cute)
            .expect("backward cute pipeline");
        assert!(r.source.unwrap().contains("flash_bwd_dq"));
    }

    #[test]
    fn tuned_backward_pipeline_threads_schedule_into_all_parts() {
        let spec = OpSpec::benchmark(AttnVariant::Mha, 2048, 64, true)
            .with_direction(Direction::Backward);
        let arch = GpuArch::a100();
        let mut tuner = crate::autotune::Autotuner::in_memory();
        let r = run_tuned(&spec, &arch, &LlmProfile::deepseek_v3(), Target::Pallas, &mut tuner)
            .expect("tuned backward pipeline");
        let tune = r.tune.as_ref().expect("tune outcome");
        for (g, part) in &r.backward {
            let params = part.program.params();
            assert_eq!(params["BM"] as usize, tune.candidate.bm, "{g}");
            assert_eq!(params["BN"] as usize, tune.candidate.bn, "{g}");
        }
    }

    #[test]
    fn forward_runs_carry_no_backward_bundle() {
        let spec = OpSpec::benchmark(AttnVariant::Mha, 2048, 64, true);
        let r = run(&spec, &GpuArch::a100(), &LlmProfile::deepseek_v3(), Target::Pallas)
            .expect("pipeline");
        assert!(r.backward.is_empty());
    }

    #[test]
    fn pipeline_wall_clock_well_under_paper_budget() {
        // Table 4: LLM-TL takes ~10 minutes with a live LLM; our rule
        // engine must run in milliseconds (<50 ms per DESIGN.md §7).
        let spec = OpSpec::benchmark(AttnVariant::Mha, 16384, 128, true);
        let r = run(&spec, &GpuArch::a100(), &LlmProfile::deepseek_r1(), Target::Pallas)
            .expect("pipeline");
        // Debug builds run the O(n^3) verification probe unoptimized, so
        // the bound here is generous; the release-mode target (<50 ms,
        // DESIGN.md §7) is enforced by `cargo bench pipeline` and recorded
        // in EXPERIMENTS.md §Perf.
        assert!(
            r.timings.total() < Duration::from_secs(10),
            "pipeline too slow: {:?}",
            r.timings.total()
        );
    }
}
