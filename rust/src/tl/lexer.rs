//! Line-oriented lexer for TL text.
//!
//! `//` and `#` start comments running to end of line. Blank lines produce
//! no tokens; each non-blank line ends with a single `Newline` token.

use super::error::TlError;
use super::token::{SpannedTok, Tok};

pub fn lex(input: &str) -> Result<Vec<SpannedTok>, TlError> {
    let mut out = Vec::new();
    for (lineno0, raw_line) in input.lines().enumerate() {
        let line_no = lineno0 + 1;
        // Strip comments.
        let mut line = raw_line;
        if let Some(pos) = find_comment(line) {
            line = &line[..pos];
        }
        let mut chars = line.char_indices().peekable();
        let start_len = out.len();
        while let Some(&(i, c)) = chars.peek() {
            match c {
                ' ' | '\t' | '\r' => {
                    chars.next();
                }
                '(' => push1(&mut out, &mut chars, Tok::LParen, line_no),
                ')' => push1(&mut out, &mut chars, Tok::RParen, line_no),
                '[' => push1(&mut out, &mut chars, Tok::LBracket, line_no),
                ']' => push1(&mut out, &mut chars, Tok::RBracket, line_no),
                ',' => push1(&mut out, &mut chars, Tok::Comma, line_no),
                ':' => push1(&mut out, &mut chars, Tok::Colon, line_no),
                '+' => push1(&mut out, &mut chars, Tok::Plus, line_no),
                '*' => push1(&mut out, &mut chars, Tok::Star, line_no),
                '/' => push1(&mut out, &mut chars, Tok::Slash, line_no),
                '.' => push1(&mut out, &mut chars, Tok::Dot, line_no),
                '-' => push1(&mut out, &mut chars, Tok::Minus, line_no),
                '=' => {
                    chars.next();
                    if matches!(chars.peek(), Some(&(_, '='))) {
                        chars.next();
                        out.push(SpannedTok { tok: Tok::EqEq, line: line_no });
                    } else {
                        out.push(SpannedTok { tok: Tok::Eq, line: line_no });
                    }
                }
                '!' => {
                    chars.next();
                    if matches!(chars.peek(), Some(&(_, '='))) {
                        chars.next();
                        out.push(SpannedTok { tok: Tok::Ne, line: line_no });
                    } else {
                        return Err(TlError::new(line_no, "unexpected '!'"));
                    }
                }
                '<' => {
                    chars.next();
                    if matches!(chars.peek(), Some(&(_, '='))) {
                        chars.next();
                        out.push(SpannedTok { tok: Tok::Le, line: line_no });
                    } else {
                        out.push(SpannedTok { tok: Tok::Lt, line: line_no });
                    }
                }
                '>' => {
                    chars.next();
                    if matches!(chars.peek(), Some(&(_, '='))) {
                        chars.next();
                        out.push(SpannedTok { tok: Tok::Ge, line: line_no });
                    } else {
                        out.push(SpannedTok { tok: Tok::Gt, line: line_no });
                    }
                }
                '0'..='9' => {
                    let mut j = i;
                    while let Some(&(k, d)) = chars.peek() {
                        if d.is_ascii_digit() {
                            j = k;
                            chars.next();
                        } else {
                            break;
                        }
                    }
                    let text = &line[i..=j];
                    let v: i64 = text
                        .parse()
                        .map_err(|_| TlError::new(line_no, format!("bad integer `{text}`")))?;
                    out.push(SpannedTok { tok: Tok::Int(v), line: line_no });
                }
                c if c.is_alphabetic() || c == '_' => {
                    let mut j = i;
                    while let Some(&(k, d)) = chars.peek() {
                        if d.is_alphanumeric() || d == '_' {
                            j = k;
                            chars.next();
                        } else {
                            break;
                        }
                    }
                    out.push(SpannedTok { tok: Tok::Ident(line[i..=j].to_string()), line: line_no });
                }
                other => {
                    return Err(TlError::new(line_no, format!("unexpected character `{other}`")));
                }
            }
        }
        if out.len() > start_len {
            out.push(SpannedTok { tok: Tok::Newline, line: line_no });
        }
    }
    let last_line = input.lines().count();
    out.push(SpannedTok { tok: Tok::Eof, line: last_line + 1 });
    Ok(out)
}

fn push1(
    out: &mut Vec<SpannedTok>,
    chars: &mut std::iter::Peekable<std::str::CharIndices<'_>>,
    tok: Tok,
    line: usize,
) {
    chars.next();
    out.push(SpannedTok { tok, line });
}

/// Find the byte offset where a `//` or `#` comment begins, if any.
fn find_comment(line: &str) -> Option<usize> {
    let bytes = line.as_bytes();
    for i in 0..bytes.len() {
        if bytes[i] == b'#' {
            return Some(i);
        }
        if bytes[i] == b'/' && i + 1 < bytes.len() && bytes[i + 1] == b'/' {
            return Some(i);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(input: &str) -> Vec<Tok> {
        lex(input).unwrap().into_iter().map(|s| s.tok).collect()
    }

    #[test]
    fn lex_copy_statement() {
        let t = toks("Copy Q from global to shared");
        assert_eq!(
            t,
            vec![
                Tok::Ident("Copy".into()),
                Tok::Ident("Q".into()),
                Tok::Ident("from".into()),
                Tok::Ident("global".into()),
                Tok::Ident("to".into()),
                Tok::Ident("shared".into()),
                Tok::Newline,
                Tok::Eof,
            ]
        );
    }

    #[test]
    fn lex_coordinate_clause() {
        let t = toks("Copy Q (BM, HeadDim) in coordinate [L = block_idx] from global to shared");
        assert!(t.contains(&Tok::LBracket));
        assert!(t.contains(&Tok::Eq));
        assert!(t.contains(&Tok::Ident("block_idx".into())));
    }

    #[test]
    fn lex_comments_stripped() {
        let t = toks("Compute Softmax S // online softmax\n# whole-line comment\nCompute Exp S");
        assert_eq!(
            t,
            vec![
                Tok::Ident("Compute".into()),
                Tok::Ident("Softmax".into()),
                Tok::Ident("S".into()),
                Tok::Newline,
                Tok::Ident("Compute".into()),
                Tok::Ident("Exp".into()),
                Tok::Ident("S".into()),
                Tok::Newline,
                Tok::Eof,
            ]
        );
    }

    #[test]
    fn lex_expression_tokens() {
        let t = toks("if i < (kv_len/BN) - 1");
        assert_eq!(
            t,
            vec![
                Tok::Ident("if".into()),
                Tok::Ident("i".into()),
                Tok::Lt,
                Tok::LParen,
                Tok::Ident("kv_len".into()),
                Tok::Slash,
                Tok::Ident("BN".into()),
                Tok::RParen,
                Tok::Minus,
                Tok::Int(1),
                Tok::Newline,
                Tok::Eof,
            ]
        );
    }

    #[test]
    fn lex_transpose_dot() {
        let t = toks("Compute GEMM Q_shared, K_shared.T and get S");
        assert!(t.contains(&Tok::Dot));
        assert!(t.contains(&Tok::Ident("T".into())));
    }

    #[test]
    fn lex_comparison_ops() {
        assert_eq!(toks("a <= b")[1], Tok::Le);
        assert_eq!(toks("a >= b")[1], Tok::Ge);
        assert_eq!(toks("a == b")[1], Tok::EqEq);
        assert_eq!(toks("a != b")[1], Tok::Ne);
    }

    #[test]
    fn lex_bad_char_errors() {
        assert!(lex("Copy Q @ global").is_err());
    }

    #[test]
    fn blank_lines_skipped() {
        let t = toks("\n\nCopy Q from global to shared\n\n");
        assert_eq!(t.iter().filter(|t| **t == Tok::Newline).count(), 1);
    }
}
