//! AST for LLM-TL.
//!
//! The statement set follows §3 and Appendix D of the paper: `Copy` and
//! `Compute` are the two fundamental statement families of the TL Sketch;
//! `Allocate`, coordinate clauses and `Reshape` are added by the stage-1b
//! parameter-reasoning step; `for` / `if` structure the execution flow;
//! `param` records the concrete tile sizes the reasoner chose so a fully
//! specified TL Code round-trips through text.

use std::collections::BTreeMap;

use super::expr::Expr;
use super::types::{DType, Layout, MemSpace};

/// A tensor operand, optionally transposed (`K_shared.T`). The paper's
/// Appendix-B "GEMM error" failure class is precisely dropping this formal
/// transpose marker: physically K keeps its layout (the mma instruction
/// handles it), but TL must carry `.T` so translation stays correct.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TensorRef {
    pub name: String,
    pub transposed: bool,
}

impl TensorRef {
    pub fn new(name: impl Into<String>) -> Self {
        TensorRef { name: name.into(), transposed: false }
    }

    pub fn t(name: impl Into<String>) -> Self {
        TensorRef { name: name.into(), transposed: true }
    }
}

/// Computation kinds. `Gemm`, `Softmax` and "regular computation"
/// (arithmetic) come straight from the paper's prompt (Listing 3);
/// `CausalMask`, `RowMax`, `RowSum`, `Exp` are the finer-grained ops the
/// reasoner uses when it expands the online-softmax recurrence; `Other`
/// carries user-defined ops through the pipeline untouched.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum ComputeOp {
    Gemm,
    /// Online softmax over a score tile. With a 2-element `with` list
    /// `[m, l]` it is the FlashAttention running update; with a 3-element
    /// list `[m, l, O]` the accumulator-rescale step is explicit.
    Softmax,
    CausalMask,
    /// Sliding-window mask: scores whose key position trails the query by
    /// `window` or more (`kpos <= qpos - window`, with `window` a `param`)
    /// are masked. Emitted by the reasoner for [`KvLayout::Sliding`]
    /// specs, always alongside `CausalMask`.
    ///
    /// [`KvLayout::Sliding`]: crate::sketch::spec::KvLayout::Sliding
    WindowMask,
    Multiply,
    Add,
    Subtract,
    Divide,
    Exp,
    RowMax,
    RowSum,
    Max,
    Other(String),
}

impl ComputeOp {
    pub fn parse(s: &str) -> Self {
        match s.to_ascii_lowercase().as_str() {
            "gemm" => ComputeOp::Gemm,
            "softmax" => ComputeOp::Softmax,
            "causalmask" | "mask" => ComputeOp::CausalMask,
            "windowmask" => ComputeOp::WindowMask,
            "multiply" | "mul" => ComputeOp::Multiply,
            "add" => ComputeOp::Add,
            "subtract" | "sub" => ComputeOp::Subtract,
            "divide" | "div" => ComputeOp::Divide,
            "exp" => ComputeOp::Exp,
            "rowmax" => ComputeOp::RowMax,
            "rowsum" => ComputeOp::RowSum,
            "max" => ComputeOp::Max,
            _ => ComputeOp::Other(s.to_string()),
        }
    }

    pub fn as_str(&self) -> &str {
        match self {
            ComputeOp::Gemm => "GEMM",
            ComputeOp::Softmax => "Softmax",
            ComputeOp::CausalMask => "CausalMask",
            ComputeOp::WindowMask => "WindowMask",
            ComputeOp::Multiply => "Multiply",
            ComputeOp::Add => "Add",
            ComputeOp::Subtract => "Subtract",
            ComputeOp::Divide => "Divide",
            ComputeOp::Exp => "Exp",
            ComputeOp::RowMax => "RowMax",
            ComputeOp::RowSum => "RowSum",
            ComputeOp::Max => "Max",
            ComputeOp::Other(s) => s,
        }
    }
}

/// Comparison operators in `if` guards.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CmpOp {
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
}

impl CmpOp {
    pub fn as_str(&self) -> &'static str {
        match self {
            CmpOp::Lt => "<",
            CmpOp::Le => "<=",
            CmpOp::Gt => ">",
            CmpOp::Ge => ">=",
            CmpOp::Eq => "==",
            CmpOp::Ne => "!=",
        }
    }

    pub fn eval(&self, a: i64, b: i64) -> bool {
        match self {
            CmpOp::Lt => a < b,
            CmpOp::Le => a <= b,
            CmpOp::Gt => a > b,
            CmpOp::Ge => a >= b,
            CmpOp::Eq => a == b,
            CmpOp::Ne => a != b,
        }
    }
}

/// A TL statement.
#[derive(Debug, Clone, PartialEq)]
pub enum Stmt {
    /// `param BM = 64` — a concrete binding chosen by the reasoner.
    Param { name: String, value: i64 },
    /// `Allocate A in global (M, K) with offset batch_offset [as f16]`
    Allocate {
        name: String,
        space: MemSpace,
        shape: Vec<Expr>,
        offset: Option<Expr>,
        dtype: Option<DType>,
    },
    /// `Copy A [(BM, BK)] [in coordinate [L = i]] from global to shared`
    Copy {
        tensor: String,
        shape: Option<Vec<Expr>>,
        coord: Vec<(String, Expr)>,
        src: MemSpace,
        dst: MemSpace,
    },
    /// `Compute <Op> in1[, in2...] [in coordinate [...]] [with a and b]
    ///  [and get [new] X | and accumulate X]`
    Compute {
        op: ComputeOp,
        inputs: Vec<TensorRef>,
        coord: Vec<(String, Expr)>,
        with: Vec<String>,
        output: Option<String>,
        accumulate: bool,
        new_var: bool,
    },
    /// `Reshape G from (MMA_C, MMA_M, MMA_N) to (MMA_A, MMA_M, MMA_N_new)`
    Reshape { tensor: String, from: Layout, to: Layout },
    /// `for i = 0:N ... end`
    For { var: String, start: Expr, end: Expr, body: Vec<Stmt> },
    /// `if i < (kv_len/BN) - 1 ... end`
    If { lhs: Expr, op: CmpOp, rhs: Expr, body: Vec<Stmt> },
}

impl Stmt {
    /// Recursively visit this statement and all nested statements.
    pub fn walk<'a>(&'a self, f: &mut impl FnMut(&'a Stmt)) {
        f(self);
        match self {
            Stmt::For { body, .. } | Stmt::If { body, .. } => {
                for s in body {
                    s.walk(f);
                }
            }
            _ => {}
        }
    }
}

/// A complete TL program: either a TL *Sketch* (execution flow only — no
/// `Allocate`/`param`/coordinates yet) or a fully-reasoned TL *Code*.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TlProgram {
    /// Human-readable kernel name (not part of the surface syntax).
    pub name: String,
    pub stmts: Vec<Stmt>,
}

impl TlProgram {
    pub fn new(name: impl Into<String>, stmts: Vec<Stmt>) -> Self {
        TlProgram { name: name.into(), stmts }
    }

    /// Collect `param` bindings into an environment.
    pub fn params(&self) -> BTreeMap<String, i64> {
        let mut env = BTreeMap::new();
        for s in &self.stmts {
            if let Stmt::Param { name, value } = s {
                env.insert(name.clone(), *value);
            }
        }
        env
    }

    /// Visit every statement (depth-first).
    pub fn walk<'a>(&'a self, mut f: impl FnMut(&'a Stmt)) {
        for s in &self.stmts {
            s.walk(&mut f);
        }
    }

    /// Total statement count including nested bodies — the paper's
    /// "a mere dozen lines of TL" metric.
    pub fn stmt_count(&self) -> usize {
        let mut n = 0;
        self.walk(|_| n += 1);
        n
    }

    /// True if the program contains stage-1b artifacts (`Allocate`,
    /// coordinates, `param`) — i.e. it is TL Code rather than a TL Sketch.
    pub fn is_reasoned(&self) -> bool {
        let mut reasoned = false;
        self.walk(|s| match s {
            Stmt::Param { .. } | Stmt::Allocate { .. } => reasoned = true,
            Stmt::Copy { coord, .. } if !coord.is_empty() => reasoned = true,
            _ => {}
        });
        reasoned
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_op_parse_roundtrip() {
        for op in [
            ComputeOp::Gemm,
            ComputeOp::Softmax,
            ComputeOp::CausalMask,
            ComputeOp::Multiply,
            ComputeOp::Divide,
            ComputeOp::Exp,
            ComputeOp::RowMax,
            ComputeOp::RowSum,
        ] {
            assert_eq!(ComputeOp::parse(op.as_str()), op);
        }
        assert_eq!(ComputeOp::parse("RoPE"), ComputeOp::Other("RoPE".into()));
    }

    #[test]
    fn cmp_op_eval() {
        assert!(CmpOp::Lt.eval(1, 2));
        assert!(!CmpOp::Lt.eval(2, 2));
        assert!(CmpOp::Le.eval(2, 2));
        assert!(CmpOp::Ne.eval(1, 2));
    }

    #[test]
    fn program_params() {
        let p = TlProgram::new(
            "t",
            vec![
                Stmt::Param { name: "BM".into(), value: 64 },
                Stmt::Param { name: "BN".into(), value: 32 },
            ],
        );
        let env = p.params();
        assert_eq!(env["BM"], 64);
        assert_eq!(env["BN"], 32);
    }

    #[test]
    fn walk_counts_nested() {
        let p = TlProgram::new(
            "t",
            vec![Stmt::For {
                var: "i".into(),
                start: Expr::int(0),
                end: Expr::int(4),
                body: vec![Stmt::Compute {
                    op: ComputeOp::Softmax,
                    inputs: vec![TensorRef::new("S")],
                    coord: vec![],
                    with: vec![],
                    output: None,
                    accumulate: false,
                    new_var: false,
                }],
            }],
        );
        assert_eq!(p.stmt_count(), 2);
        assert!(!p.is_reasoned());
    }
}
