//! Recursive-descent parser for TL text.
//!
//! Grammar (line-oriented; keywords case-insensitive; `end` closes `for`
//! and `if` blocks):
//!
//! ```text
//! stmt     := param | allocate | copy | compute | reshape | for | if
//! param    := "param" IDENT "=" INT
//! allocate := "Allocate" IDENT "in" memspace shape ["with" "offset" expr] ["as" dtype]
//! copy     := "Copy" IDENT [shape] [coord] "from" memspace "to" memspace
//! compute  := "Compute" OP operands [coord] [with] ["and" ("get" ["new"] IDENT | "accumulate" IDENT)]
//! reshape  := "Reshape" IDENT "from" layout "to" layout
//! for      := "for" IDENT "=" expr ":" expr NL stmt* "end"
//! if       := "if" expr CMP expr NL stmt* "end"
//! coord    := "in" ("coordinate" | "coor") "[" IDENT "=" expr ("," IDENT "=" expr)* "]"
//! with     := "with" IDENT (("and" | ",") IDENT)*
//! ```

use super::ast::{CmpOp, ComputeOp, Stmt, TensorRef, TlProgram};
use super::error::TlError;
use super::expr::{BinOp, Expr};
use super::lexer::lex;
use super::token::{SpannedTok, Tok};
use super::types::{DType, Frag, Layout, MemSpace};

pub fn parse_program(input: &str) -> Result<TlProgram, TlError> {
    let toks = lex(input)?;
    let mut p = Parser { toks, pos: 0 };
    let stmts = p.parse_block(/*top_level=*/ true)?;
    Ok(TlProgram::new("tl_program", stmts))
}

struct Parser {
    toks: Vec<SpannedTok>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> &Tok {
        &self.toks[self.pos].tok
    }

    fn line(&self) -> usize {
        self.toks[self.pos].line
    }

    fn next(&mut self) -> Tok {
        let t = self.toks[self.pos].tok.clone();
        if self.pos + 1 < self.toks.len() {
            self.pos += 1;
        }
        t
    }

    fn err(&self, msg: impl Into<String>) -> TlError {
        TlError::new(self.line(), msg)
    }

    fn expect(&mut self, tok: &Tok) -> Result<(), TlError> {
        if self.peek() == tok {
            self.next();
            Ok(())
        } else {
            Err(self.err(format!("expected `{tok}`, found `{}`", self.peek())))
        }
    }

    /// Consume a keyword (case-insensitive identifier match).
    fn expect_kw(&mut self, kw: &str) -> Result<(), TlError> {
        if self.peek_kw(kw) {
            self.next();
            Ok(())
        } else {
            Err(self.err(format!("expected `{kw}`, found `{}`", self.peek())))
        }
    }

    fn peek_kw(&self, kw: &str) -> bool {
        matches!(self.peek(), Tok::Ident(s) if s.eq_ignore_ascii_case(kw))
    }

    fn ident(&mut self) -> Result<String, TlError> {
        match self.next() {
            Tok::Ident(s) => Ok(s),
            other => Err(self.err(format!("expected identifier, found `{other}`"))),
        }
    }

    fn newline(&mut self) -> Result<(), TlError> {
        match self.peek() {
            Tok::Newline => {
                self.next();
                Ok(())
            }
            Tok::Eof => Ok(()),
            other => Err(self.err(format!("expected end of line, found `{other}`"))),
        }
    }

    fn parse_block(&mut self, top_level: bool) -> Result<Vec<Stmt>, TlError> {
        let mut stmts = Vec::new();
        loop {
            match self.peek() {
                Tok::Eof => {
                    if top_level {
                        return Ok(stmts);
                    }
                    return Err(self.err("unexpected end of input inside block (missing `end`)"));
                }
                Tok::Newline => {
                    self.next();
                }
                Tok::Ident(s) if s.eq_ignore_ascii_case("end") => {
                    if top_level {
                        return Err(self.err("`end` without matching `for`/`if`"));
                    }
                    self.next();
                    self.newline()?;
                    return Ok(stmts);
                }
                _ => stmts.push(self.parse_stmt()?),
            }
        }
    }

    fn parse_stmt(&mut self) -> Result<Stmt, TlError> {
        let kw = match self.peek() {
            Tok::Ident(s) => s.to_ascii_lowercase(),
            other => return Err(self.err(format!("expected statement, found `{other}`"))),
        };
        match kw.as_str() {
            "param" => self.parse_param(),
            "allocate" => self.parse_allocate(),
            "copy" => self.parse_copy(),
            "compute" => self.parse_compute(),
            "reshape" => self.parse_reshape(),
            "for" => self.parse_for(),
            "if" => self.parse_if(),
            other => Err(self.err(format!("unknown statement `{other}`"))),
        }
    }

    fn parse_param(&mut self) -> Result<Stmt, TlError> {
        self.expect_kw("param")?;
        let name = self.ident()?;
        self.expect(&Tok::Eq)?;
        let value = match self.next() {
            Tok::Int(v) => v,
            Tok::Minus => match self.next() {
                Tok::Int(v) => -v,
                other => return Err(self.err(format!("expected integer, found `{other}`"))),
            },
            other => return Err(self.err(format!("expected integer, found `{other}`"))),
        };
        self.newline()?;
        Ok(Stmt::Param { name, value })
    }

    fn parse_allocate(&mut self) -> Result<Stmt, TlError> {
        self.expect_kw("allocate")?;
        let name = self.ident()?;
        self.expect_kw("in")?;
        let space = self.memspace()?;
        let shape = self.parse_shape()?;
        let mut offset = None;
        if self.peek_kw("with") {
            self.next();
            self.expect_kw("offset")?;
            offset = Some(self.parse_expr()?);
        }
        let mut dtype = None;
        if self.peek_kw("as") {
            self.next();
            let d = self.ident()?;
            dtype = Some(
                DType::parse(&d).ok_or_else(|| self.err(format!("unknown dtype `{d}`")))?,
            );
        }
        self.newline()?;
        Ok(Stmt::Allocate { name, space, shape, offset, dtype })
    }

    fn parse_copy(&mut self) -> Result<Stmt, TlError> {
        self.expect_kw("copy")?;
        let tensor = self.ident()?;
        let shape = if matches!(self.peek(), Tok::LParen) {
            Some(self.parse_shape()?)
        } else {
            None
        };
        let mut coord = Vec::new();
        if self.peek_kw("in") {
            coord = self.parse_coord()?;
        }
        self.expect_kw("from")?;
        let src = self.memspace()?;
        self.expect_kw("to")?;
        let dst = self.memspace()?;
        self.newline()?;
        Ok(Stmt::Copy { tensor, shape, coord, src, dst })
    }

    fn parse_compute(&mut self) -> Result<Stmt, TlError> {
        self.expect_kw("compute")?;
        let op_name = self.ident()?;
        let op = ComputeOp::parse(&op_name);
        // Operand list: tensor refs until `and` / `with` / `in` / newline.
        let mut inputs = Vec::new();
        loop {
            match self.peek() {
                Tok::Ident(s)
                    if s.eq_ignore_ascii_case("and")
                        || s.eq_ignore_ascii_case("with")
                        || s.eq_ignore_ascii_case("in") =>
                {
                    break
                }
                Tok::Ident(_) => {
                    inputs.push(self.parse_tensor_ref()?);
                    if matches!(self.peek(), Tok::Comma) {
                        self.next();
                    }
                }
                _ => break,
            }
        }
        let mut coord = Vec::new();
        if self.peek_kw("in") {
            coord = self.parse_coord()?;
        }
        let mut with = Vec::new();
        if self.peek_kw("with") {
            self.next();
            with.push(self.ident()?);
            loop {
                if matches!(self.peek(), Tok::Comma) {
                    self.next();
                    with.push(self.ident()?);
                } else if self.peek_kw("and") {
                    // `and` either continues the with-list or starts the
                    // output tail (`and get` / `and accumulate`).
                    let save = self.pos;
                    self.next();
                    if self.peek_kw("get") || self.peek_kw("accumulate") {
                        self.pos = save;
                        break;
                    }
                    with.push(self.ident()?);
                } else {
                    break;
                }
            }
        }
        let mut output = None;
        let mut accumulate = false;
        let mut new_var = false;
        if self.peek_kw("and") {
            self.next();
            if self.peek_kw("get") {
                self.next();
                if self.peek_kw("new") {
                    self.next();
                    new_var = true;
                }
                output = Some(self.ident()?);
            } else if self.peek_kw("accumulate") {
                self.next();
                accumulate = true;
                output = Some(self.ident()?);
            } else {
                return Err(self.err("expected `get` or `accumulate` after `and`"));
            }
        }
        self.newline()?;
        Ok(Stmt::Compute { op, inputs, coord, with, output, accumulate, new_var })
    }

    fn parse_reshape(&mut self) -> Result<Stmt, TlError> {
        self.expect_kw("reshape")?;
        let tensor = self.ident()?;
        self.expect_kw("from")?;
        let from = self.parse_layout()?;
        self.expect_kw("to")?;
        let to = self.parse_layout()?;
        self.newline()?;
        Ok(Stmt::Reshape { tensor, from, to })
    }

    fn parse_for(&mut self) -> Result<Stmt, TlError> {
        self.expect_kw("for")?;
        let var = self.ident()?;
        self.expect(&Tok::Eq)?;
        let start = self.parse_expr()?;
        self.expect(&Tok::Colon)?;
        let end = self.parse_expr()?;
        self.newline()?;
        let body = self.parse_block(false)?;
        Ok(Stmt::For { var, start, end, body })
    }

    fn parse_if(&mut self) -> Result<Stmt, TlError> {
        self.expect_kw("if")?;
        let lhs = self.parse_expr()?;
        let op = match self.next() {
            Tok::Lt => CmpOp::Lt,
            Tok::Le => CmpOp::Le,
            Tok::Gt => CmpOp::Gt,
            Tok::Ge => CmpOp::Ge,
            Tok::EqEq => CmpOp::Eq,
            Tok::Ne => CmpOp::Ne,
            other => return Err(self.err(format!("expected comparison, found `{other}`"))),
        };
        let rhs = self.parse_expr()?;
        self.newline()?;
        let body = self.parse_block(false)?;
        Ok(Stmt::If { lhs, op, rhs, body })
    }

    fn parse_tensor_ref(&mut self) -> Result<TensorRef, TlError> {
        let name = self.ident()?;
        let mut transposed = false;
        if matches!(self.peek(), Tok::Dot) {
            self.next();
            let t = self.ident()?;
            if !t.eq_ignore_ascii_case("t") {
                return Err(self.err(format!("expected `.T` transpose marker, found `.{t}`")));
            }
            transposed = true;
        }
        Ok(TensorRef { name, transposed })
    }

    fn memspace(&mut self) -> Result<MemSpace, TlError> {
        let s = self.ident()?;
        MemSpace::parse(&s).ok_or_else(|| self.err(format!("unknown memory space `{s}`")))
    }

    fn parse_shape(&mut self) -> Result<Vec<Expr>, TlError> {
        self.expect(&Tok::LParen)?;
        let mut dims = vec![self.parse_expr()?];
        while matches!(self.peek(), Tok::Comma) {
            self.next();
            dims.push(self.parse_expr()?);
        }
        self.expect(&Tok::RParen)?;
        Ok(dims)
    }

    fn parse_coord(&mut self) -> Result<Vec<(String, Expr)>, TlError> {
        self.expect_kw("in")?;
        if self.peek_kw("coordinate") || self.peek_kw("coor") {
            self.next();
        } else {
            return Err(self.err("expected `coordinate` after `in`"));
        }
        self.expect(&Tok::LBracket)?;
        let mut coords = Vec::new();
        loop {
            let name = self.ident()?;
            self.expect(&Tok::Eq)?;
            let e = self.parse_expr()?;
            coords.push((name, e));
            if matches!(self.peek(), Tok::Comma) {
                self.next();
            } else {
                break;
            }
        }
        self.expect(&Tok::RBracket)?;
        Ok(coords)
    }

    fn parse_layout(&mut self) -> Result<Layout, TlError> {
        if matches!(self.peek(), Tok::LParen) {
            self.next();
            let first = self.ident()?;
            let frag = Frag::parse(&first)
                .ok_or_else(|| self.err(format!("unknown mma fragment `{first}`")))?;
            let mut dims = Vec::new();
            while matches!(self.peek(), Tok::Comma) {
                self.next();
                dims.push(self.ident()?);
            }
            self.expect(&Tok::RParen)?;
            Ok(Layout { frag, dims })
        } else {
            let s = self.ident()?;
            let frag =
                Frag::parse(&s).ok_or_else(|| self.err(format!("unknown mma fragment `{s}`")))?;
            Ok(Layout::frag_only(frag))
        }
    }

    // Expression parsing: precedence climbing.
    fn parse_expr(&mut self) -> Result<Expr, TlError> {
        let mut lhs = self.parse_term()?;
        loop {
            let op = match self.peek() {
                Tok::Plus => BinOp::Add,
                Tok::Minus => BinOp::Sub,
                _ => break,
            };
            self.next();
            let rhs = self.parse_term()?;
            lhs = Expr::Bin(op, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn parse_term(&mut self) -> Result<Expr, TlError> {
        let mut lhs = self.parse_factor()?;
        loop {
            let op = match self.peek() {
                Tok::Star => BinOp::Mul,
                Tok::Slash => BinOp::Div,
                _ => break,
            };
            self.next();
            let rhs = self.parse_factor()?;
            lhs = Expr::Bin(op, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn parse_factor(&mut self) -> Result<Expr, TlError> {
        match self.next() {
            Tok::Int(v) => Ok(Expr::Int(v)),
            Tok::Ident(s) => {
                // Coordinate-gather form: `block_table[i]`.
                if matches!(self.peek(), Tok::LBracket) {
                    self.next();
                    let idx = self.parse_expr()?;
                    self.expect(&Tok::RBracket)?;
                    return Ok(Expr::Idx(s, Box::new(idx)));
                }
                Ok(Expr::Sym(s))
            }
            Tok::Minus => {
                let inner = self.parse_factor()?;
                Ok(Expr::Bin(BinOp::Sub, Box::new(Expr::Int(0)), Box::new(inner)))
            }
            Tok::LParen => {
                let e = self.parse_expr()?;
                self.expect(&Tok::RParen)?;
                Ok(e)
            }
            other => Err(self.err(format!("expected expression, found `{other}`"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sketch_copy() {
        let p = parse_program("Copy Q from global to shared").unwrap();
        assert_eq!(
            p.stmts,
            vec![Stmt::Copy {
                tensor: "Q".into(),
                shape: None,
                coord: vec![],
                src: MemSpace::Global,
                dst: MemSpace::Shared,
            }]
        );
    }

    #[test]
    fn parse_full_copy_with_coordinate() {
        let p = parse_program(
            "Copy Q (BM, HeadDim) in coordinate [L = block_idx] from global to shared",
        )
        .unwrap();
        match &p.stmts[0] {
            Stmt::Copy { tensor, shape, coord, src, dst } => {
                assert_eq!(tensor, "Q");
                assert_eq!(
                    shape.as_ref().unwrap(),
                    &vec![Expr::sym("BM"), Expr::sym("HeadDim")]
                );
                assert_eq!(coord, &vec![("L".to_string(), Expr::sym("block_idx"))]);
                assert_eq!(*src, MemSpace::Global);
                assert_eq!(*dst, MemSpace::Shared);
            }
            other => panic!("wrong stmt {other:?}"),
        }
    }

    #[test]
    fn parse_gemm_with_transpose() {
        let p = parse_program("Compute GEMM Q_shared, K_shared.T and get S").unwrap();
        match &p.stmts[0] {
            Stmt::Compute { op, inputs, output, accumulate, .. } => {
                assert_eq!(*op, ComputeOp::Gemm);
                assert_eq!(inputs, &vec![TensorRef::new("Q_shared"), TensorRef::t("K_shared")]);
                assert_eq!(output.as_deref(), Some("S"));
                assert!(!accumulate);
            }
            other => panic!("wrong stmt {other:?}"),
        }
    }

    #[test]
    fn parse_gemm_accumulate() {
        let p = parse_program("Compute GEMM S, V_shared and accumulate O_register").unwrap();
        match &p.stmts[0] {
            Stmt::Compute { accumulate, output, .. } => {
                assert!(accumulate);
                assert_eq!(output.as_deref(), Some("O_register"));
            }
            other => panic!("wrong stmt {other:?}"),
        }
    }

    #[test]
    fn parse_softmax_with_running_stats() {
        let p = parse_program("Compute Softmax S with Smax and Ssum").unwrap();
        match &p.stmts[0] {
            Stmt::Compute { op, inputs, with, .. } => {
                assert_eq!(*op, ComputeOp::Softmax);
                assert_eq!(inputs, &vec![TensorRef::new("S")]);
                assert_eq!(with, &vec!["Smax".to_string(), "Ssum".to_string()]);
            }
            other => panic!("wrong stmt {other:?}"),
        }
    }

    #[test]
    fn parse_with_list_then_output_tail() {
        let p = parse_program("Compute Softmax S with m and l and get P").unwrap();
        match &p.stmts[0] {
            Stmt::Compute { with, output, .. } => {
                assert_eq!(with, &vec!["m".to_string(), "l".to_string()]);
                assert_eq!(output.as_deref(), Some("P"));
            }
            other => panic!("wrong stmt {other:?}"),
        }
    }

    #[test]
    fn parse_multiply_get_new() {
        let p = parse_program("Compute Multiply A, x and get new A").unwrap();
        match &p.stmts[0] {
            Stmt::Compute { op, new_var, output, .. } => {
                assert_eq!(*op, ComputeOp::Multiply);
                assert!(*new_var);
                assert_eq!(output.as_deref(), Some("A"));
            }
            other => panic!("wrong stmt {other:?}"),
        }
    }

    #[test]
    fn parse_allocate_with_offset() {
        let p = parse_program("Allocate A in global (M, K) with offset batch_offset").unwrap();
        match &p.stmts[0] {
            Stmt::Allocate { name, space, shape, offset, dtype } => {
                assert_eq!(name, "A");
                assert_eq!(*space, MemSpace::Global);
                assert_eq!(shape.len(), 2);
                assert_eq!(offset.as_ref().unwrap(), &Expr::sym("batch_offset"));
                assert!(dtype.is_none());
            }
            other => panic!("wrong stmt {other:?}"),
        }
    }

    #[test]
    fn parse_allocate_register_with_dtype() {
        let p = parse_program("Allocate C in register (BM, BN) as f32").unwrap();
        match &p.stmts[0] {
            Stmt::Allocate { space, dtype, .. } => {
                assert_eq!(*space, MemSpace::Register);
                assert_eq!(*dtype, Some(DType::F32));
            }
            other => panic!("wrong stmt {other:?}"),
        }
    }

    #[test]
    fn parse_reshape_layouts() {
        let p = parse_program(
            "Reshape G from (MMA_C, MMA_M, MMA_N) to (MMA_A, MMA_M, MMA_N_new)",
        )
        .unwrap();
        match &p.stmts[0] {
            Stmt::Reshape { tensor, from, to } => {
                assert_eq!(tensor, "G");
                assert_eq!(from.frag, Frag::C);
                assert_eq!(to.frag, Frag::A);
                assert_eq!(to.dims, vec!["MMA_M".to_string(), "MMA_N_new".to_string()]);
            }
            other => panic!("wrong stmt {other:?}"),
        }
    }

    #[test]
    fn parse_reshape_shorthand() {
        let p = parse_program("reshape rS from mma_C to mma_A").unwrap();
        match &p.stmts[0] {
            Stmt::Reshape { from, to, .. } => {
                assert_eq!(from.frag, Frag::C);
                assert_eq!(to.frag, Frag::A);
                assert!(from.dims.is_empty());
            }
            other => panic!("wrong stmt {other:?}"),
        }
    }

    #[test]
    fn parse_for_loop_with_body() {
        let src = "for i = 0:kv_len/BN\n  Copy K from global to shared\n  Compute Softmax S\nend";
        let p = parse_program(src).unwrap();
        match &p.stmts[0] {
            Stmt::For { var, start, end, body } => {
                assert_eq!(var, "i");
                assert_eq!(*start, Expr::int(0));
                assert_eq!(*end, Expr::div(Expr::sym("kv_len"), Expr::sym("BN")));
                assert_eq!(body.len(), 2);
            }
            other => panic!("wrong stmt {other:?}"),
        }
    }

    #[test]
    fn parse_if_guard() {
        let src = "if i < (kv_len/BN) - 1\n  Copy K (BN, HeadDim) in coordinate [L = i+1] from global to shared\nend";
        let p = parse_program(src).unwrap();
        match &p.stmts[0] {
            Stmt::If { op, body, .. } => {
                assert_eq!(*op, CmpOp::Lt);
                assert_eq!(body.len(), 1);
            }
            other => panic!("wrong stmt {other:?}"),
        }
    }

    #[test]
    fn parse_listing1_from_paper() {
        // Appendix B, Listing 1 (the reshape-omission failure case) must
        // parse — the *verifier*, not the parser, rejects it.
        let src = "\
Compute GEMM Q_shared, K_shared.T and get S
if i < (kv_len/BN) - 1
  Copy K (BN, HeadDim) in coordinate [L = i+1] from global to shared
end
Compute Softmax S
Compute GEMM S, V_shared and accumulate O_register
";
        let p = parse_program(src).unwrap();
        assert_eq!(p.stmts.len(), 4);
    }

    #[test]
    fn parse_gather_coordinate() {
        let p = parse_program(
            "Copy K (BN, HeadDim) in coordinate [H = h, L = block_table[i + 1]] from global to shared",
        )
        .unwrap();
        match &p.stmts[0] {
            Stmt::Copy { coord, .. } => {
                assert_eq!(coord.len(), 2);
                assert_eq!(
                    coord[1],
                    (
                        "L".to_string(),
                        Expr::idx("block_table", Expr::add(Expr::sym("i"), Expr::int(1)))
                    )
                );
            }
            other => panic!("wrong stmt {other:?}"),
        }
    }

    #[test]
    fn parse_param() {
        let p = parse_program("param BM = 64\nparam BN = 32").unwrap();
        assert_eq!(p.params()["BM"], 64);
        assert_eq!(p.params()["BN"], 32);
    }

    #[test]
    fn missing_end_errors() {
        assert!(parse_program("for i = 0:4\nCompute Softmax S").is_err());
    }

    #[test]
    fn stray_end_errors() {
        assert!(parse_program("end").is_err());
    }

    #[test]
    fn unknown_statement_errors() {
        let e = parse_program("Transmogrify Q").unwrap_err();
        assert!(e.message.contains("unknown statement"));
    }

    #[test]
    fn unknown_memspace_errors() {
        assert!(parse_program("Copy Q from vmem to shared").is_err());
    }
}
