//! Error types for the TL language layer.

use std::fmt;

/// Lexing/parsing error with line information.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TlError {
    pub line: usize,
    pub message: String,
}

impl TlError {
    pub fn new(line: usize, message: impl Into<String>) -> Self {
        TlError { line, message: message.into() }
    }
}

impl fmt::Display for TlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "TL error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for TlError {}
