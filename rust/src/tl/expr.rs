//! Symbolic integer expressions used for TL dimensions, coordinates and
//! loop bounds: `BM`, `HeadDim`, `kv_len/BN`, `(kv_len/BN) - 1`, `i + 1`.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
}

impl BinOp {
    pub fn as_str(&self) -> &'static str {
        match self {
            BinOp::Add => "+",
            BinOp::Sub => "-",
            BinOp::Mul => "*",
            BinOp::Div => "/",
        }
    }
}

/// A symbolic integer expression over named parameters and loop variables.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Expr {
    Int(i64),
    Sym(String),
    Bin(BinOp, Box<Expr>, Box<Expr>),
    /// Block-table gather: `block_table[i]` — the coordinate-gather form
    /// used by paged-KV `Copy` statements. The named table is an integer
    /// array supplied by the host at execution time (not an `i64`
    /// binding), so plain [`Expr::eval`] rejects it; the TL engines
    /// resolve it against their block tables.
    Idx(String, Box<Expr>),
}

impl Expr {
    pub fn sym(s: impl Into<String>) -> Self {
        Expr::Sym(s.into())
    }

    pub fn int(v: i64) -> Self {
        Expr::Int(v)
    }

    pub fn add(a: Expr, b: Expr) -> Self {
        Expr::Bin(BinOp::Add, Box::new(a), Box::new(b))
    }

    pub fn sub(a: Expr, b: Expr) -> Self {
        Expr::Bin(BinOp::Sub, Box::new(a), Box::new(b))
    }

    pub fn mul(a: Expr, b: Expr) -> Self {
        Expr::Bin(BinOp::Mul, Box::new(a), Box::new(b))
    }

    pub fn div(a: Expr, b: Expr) -> Self {
        Expr::Bin(BinOp::Div, Box::new(a), Box::new(b))
    }

    pub fn idx(table: impl Into<String>, index: Expr) -> Self {
        Expr::Idx(table.into(), Box::new(index))
    }

    /// The gather table this expression reads through, if any.
    pub fn gather(&self) -> Option<(&str, &Expr)> {
        match self {
            Expr::Idx(t, e) => Some((t.as_str(), e)),
            _ => None,
        }
    }

    /// Evaluate under a binding environment. `Div` is exact integer
    /// division in TL (dimensions are chosen to divide evenly; the
    /// verifier checks this); evaluation uses floor division and flags
    /// division by zero.
    pub fn eval(&self, env: &BTreeMap<String, i64>) -> Result<i64, String> {
        match self {
            Expr::Int(v) => Ok(*v),
            Expr::Sym(s) => env
                .get(s)
                .copied()
                .ok_or_else(|| format!("unbound symbol `{s}`")),
            Expr::Bin(op, a, b) => {
                let a = a.eval(env)?;
                let b = b.eval(env)?;
                match op {
                    BinOp::Add => Ok(a + b),
                    BinOp::Sub => Ok(a - b),
                    BinOp::Mul => Ok(a * b),
                    BinOp::Div => {
                        if b == 0 {
                            Err("division by zero".to_string())
                        } else {
                            Ok(a.div_euclid(b))
                        }
                    }
                }
            }
            Expr::Idx(t, _) => Err(format!(
                "gather `{t}[..]` needs a block table; only the TL engines evaluate it"
            )),
        }
    }

    /// All symbols referenced by this expression.
    pub fn symbols(&self, out: &mut Vec<String>) {
        match self {
            Expr::Int(_) => {}
            Expr::Sym(s) => {
                if !out.contains(s) {
                    out.push(s.clone());
                }
            }
            Expr::Bin(_, a, b) => {
                a.symbols(out);
                b.symbols(out);
            }
            Expr::Idx(_, e) => e.symbols(out),
        }
    }

    fn precedence(&self) -> u8 {
        match self {
            Expr::Int(_) | Expr::Sym(_) | Expr::Idx(_, _) => 3,
            Expr::Bin(BinOp::Mul | BinOp::Div, _, _) => 2,
            Expr::Bin(BinOp::Add | BinOp::Sub, _, _) => 1,
        }
    }
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::Int(v) => write!(f, "{v}"),
            Expr::Sym(s) => write!(f, "{s}"),
            Expr::Idx(t, e) => write!(f, "{t}[{e}]"),
            Expr::Bin(op, a, b) => {
                let my_prec = self.precedence();
                // Parenthesize sub-expressions of lower precedence; for the
                // non-associative ops (- /) also parenthesize an equal-
                // precedence right operand so printing is unambiguous.
                let left_needs = a.precedence() < my_prec;
                let right_needs = match op {
                    BinOp::Add | BinOp::Mul => b.precedence() < my_prec,
                    BinOp::Sub | BinOp::Div => b.precedence() <= my_prec,
                };
                if left_needs {
                    write!(f, "({a})")?;
                } else {
                    write!(f, "{a}")?;
                }
                write!(f, " {} ", op.as_str())?;
                if right_needs {
                    write!(f, "({b})")
                } else {
                    write!(f, "{b}")
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env(pairs: &[(&str, i64)]) -> BTreeMap<String, i64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn eval_basic() {
        let e = Expr::sub(Expr::div(Expr::sym("kv_len"), Expr::sym("BN")), Expr::int(1));
        assert_eq!(e.eval(&env(&[("kv_len", 1024), ("BN", 64)])).unwrap(), 15);
    }

    #[test]
    fn eval_unbound_symbol() {
        let e = Expr::sym("BM");
        assert!(e.eval(&env(&[])).unwrap_err().contains("BM"));
    }

    #[test]
    fn eval_division_by_zero() {
        let e = Expr::div(Expr::int(4), Expr::sym("z"));
        assert!(e.eval(&env(&[("z", 0)])).is_err());
    }

    #[test]
    fn display_precedence() {
        // (a + b) * c needs parens; a * b + c does not.
        let e1 = Expr::mul(Expr::add(Expr::sym("a"), Expr::sym("b")), Expr::sym("c"));
        assert_eq!(e1.to_string(), "(a + b) * c");
        let e2 = Expr::add(Expr::mul(Expr::sym("a"), Expr::sym("b")), Expr::sym("c"));
        assert_eq!(e2.to_string(), "a * b + c");
    }

    #[test]
    fn display_right_assoc_parens() {
        // a - (b - c) must keep parens.
        let e = Expr::sub(Expr::sym("a"), Expr::sub(Expr::sym("b"), Expr::sym("c")));
        assert_eq!(e.to_string(), "a - (b - c)");
    }

    #[test]
    fn gather_display_and_eval() {
        let e = Expr::idx("block_table", Expr::add(Expr::sym("i"), Expr::int(1)));
        assert_eq!(e.to_string(), "block_table[i + 1]");
        assert!(e.eval(&env(&[("i", 3)])).unwrap_err().contains("block table"));
        let mut syms = Vec::new();
        e.symbols(&mut syms);
        assert_eq!(syms, vec!["i".to_string()]);
        assert_eq!(e.gather().unwrap().0, "block_table");
    }

    #[test]
    fn symbols_dedup() {
        let e = Expr::add(Expr::sym("BM"), Expr::mul(Expr::sym("BM"), Expr::sym("BN")));
        let mut syms = Vec::new();
        e.symbols(&mut syms);
        assert_eq!(syms, vec!["BM".to_string(), "BN".to_string()]);
    }
}
