//! Core semantic types of TL: memory spaces, datatypes, mma fragment layouts.

use std::fmt;

/// GPU memory hierarchy level a tensor lives at (§2.1.1 of the paper).
///
/// In the TPU/Pallas adaptation these map to HBM (`Global`), VMEM
/// (`Shared`) and kernel-local loop-carried values (`Register`) — see
/// DESIGN.md §Hardware-Adaptation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum MemSpace {
    Global,
    Shared,
    Register,
}

impl MemSpace {
    pub fn as_str(&self) -> &'static str {
        match self {
            MemSpace::Global => "global",
            MemSpace::Shared => "shared",
            MemSpace::Register => "register",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "global" => Some(MemSpace::Global),
            "shared" => Some(MemSpace::Shared),
            "register" | "reg" => Some(MemSpace::Register),
            _ => None,
        }
    }

    /// Distance from the compute units; used by the verifier to check that
    /// `Copy` statements move data one direction at a time and by the cost
    /// model to price the transfer.
    pub fn level(&self) -> u8 {
        match self {
            MemSpace::Global => 2,
            MemSpace::Shared => 1,
            MemSpace::Register => 0,
        }
    }
}

impl fmt::Display for MemSpace {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Element datatype. FP8 (e4m3) appears in the paper's L40S case study
/// (Table 6); the paper's main tables use FP16 accumulating in FP32.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F16,
    BF16,
    F8E4M3,
}

impl DType {
    pub fn bytes(&self) -> usize {
        match self {
            DType::F32 => 4,
            DType::F16 | DType::BF16 => 2,
            DType::F8E4M3 => 1,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F16 => "f16",
            DType::BF16 => "bf16",
            DType::F8E4M3 => "f8e4m3",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => Some(DType::F32),
            "f16" | "fp16" | "float16" | "half" => Some(DType::F16),
            "bf16" | "bfloat16" => Some(DType::BF16),
            "f8e4m3" | "fp8" | "f8" | "e4m3" => Some(DType::F8E4M3),
            _ => None,
        }
    }

    /// jnp dtype name used by the Pallas backend.
    pub fn jnp_name(&self) -> &'static str {
        match self {
            DType::F32 => "jnp.float32",
            DType::F16 => "jnp.float16",
            DType::BF16 => "jnp.bfloat16",
            DType::F8E4M3 => "jnp.float8_e4m3fn",
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Tensor-Core mma fragment role (§3.2.2, footnote 1 of the paper): tiles
/// feeding an `mma` must follow hardware-defined layouts for the A, B and
/// C operands. The output of GEMM-I is produced in the `C` layout; to feed
/// it to GEMM-II as the left operand it must be *reshaped* to the `A`
/// layout — the `Reshape` statement whose omission is the paper's
/// Appendix-B "Reshape omission" failure class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Frag {
    A,
    B,
    C,
}

impl Frag {
    pub fn as_str(&self) -> &'static str {
        match self {
            Frag::A => "mma_A",
            Frag::B => "mma_B",
            Frag::C => "mma_C",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "mma_a" | "a" => Some(Frag::A),
            "mma_b" | "b" => Some(Frag::B),
            "mma_c" | "c" => Some(Frag::C),
            _ => None,
        }
    }
}

impl fmt::Display for Frag {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// An mma-level layout `(MMA_C, MMA_M, MMA_N)`: the fragment role plus the
/// named repetition dimensions along M/N (§3.2.2). `Reshape G from
/// (MMA_C, MMA_M, MMA_N) to (MMA_A, MMA_M, MMA_N_new)` changes the
/// fragment role and renames the inner repetition count.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Layout {
    pub frag: Frag,
    /// Repetition-dimension names, e.g. `["MMA_M", "MMA_N"]`. Empty for the
    /// shorthand form `reshape rS from mma_C to mma_A`.
    pub dims: Vec<String>,
}

impl Layout {
    pub fn frag_only(frag: Frag) -> Self {
        Layout { frag, dims: Vec::new() }
    }

    pub fn new(frag: Frag, dims: &[&str]) -> Self {
        Layout { frag, dims: dims.iter().map(|s| s.to_string()).collect() }
    }
}

impl fmt::Display for Layout {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.dims.is_empty() {
            write!(f, "{}", self.frag)
        } else {
            write!(f, "({}", self.frag)?;
            for d in &self.dims {
                write!(f, ", {d}")?;
            }
            write!(f, ")")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memspace_roundtrip() {
        for m in [MemSpace::Global, MemSpace::Shared, MemSpace::Register] {
            assert_eq!(MemSpace::parse(m.as_str()), Some(m));
        }
        assert_eq!(MemSpace::parse("REGISTER"), Some(MemSpace::Register));
        assert_eq!(MemSpace::parse("vmem"), None);
    }

    #[test]
    fn memspace_levels_ordered() {
        assert!(MemSpace::Global.level() > MemSpace::Shared.level());
        assert!(MemSpace::Shared.level() > MemSpace::Register.level());
    }

    #[test]
    fn dtype_bytes() {
        assert_eq!(DType::F32.bytes(), 4);
        assert_eq!(DType::F16.bytes(), 2);
        assert_eq!(DType::BF16.bytes(), 2);
        assert_eq!(DType::F8E4M3.bytes(), 1);
    }

    #[test]
    fn dtype_parse_aliases() {
        assert_eq!(DType::parse("fp16"), Some(DType::F16));
        assert_eq!(DType::parse("fp8"), Some(DType::F8E4M3));
        assert_eq!(DType::parse("bfloat16"), Some(DType::BF16));
        assert_eq!(DType::parse("int8"), None);
    }

    #[test]
    fn frag_parse() {
        assert_eq!(Frag::parse("mma_C"), Some(Frag::C));
        assert_eq!(Frag::parse("MMA_A"), Some(Frag::A));
        assert_eq!(Frag::parse("mma_d"), None);
    }

    #[test]
    fn layout_display() {
        let l = Layout::new(Frag::C, &["MMA_M", "MMA_N"]);
        assert_eq!(l.to_string(), "(mma_C, MMA_M, MMA_N)");
        assert_eq!(Layout::frag_only(Frag::A).to_string(), "mma_A");
    }
}
