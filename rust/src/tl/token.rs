//! Token kinds for the TL lexer.

use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tok {
    /// Identifier or keyword (keywords are matched case-insensitively by
    /// the parser; identifiers keep their case: `Q_shared`, `HeadDim`).
    Ident(String),
    /// Integer literal.
    Int(i64),
    /// Punctuation and operators.
    LParen,
    RParen,
    LBracket,
    RBracket,
    Comma,
    Colon,
    Eq,
    EqEq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    Plus,
    Minus,
    Star,
    Slash,
    Dot,
    /// End of a logical line. The TL grammar is line-oriented.
    Newline,
    Eof,
}

impl fmt::Display for Tok {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Tok::Ident(s) => write!(f, "{s}"),
            Tok::Int(v) => write!(f, "{v}"),
            Tok::LParen => write!(f, "("),
            Tok::RParen => write!(f, ")"),
            Tok::LBracket => write!(f, "["),
            Tok::RBracket => write!(f, "]"),
            Tok::Comma => write!(f, ","),
            Tok::Colon => write!(f, ":"),
            Tok::Eq => write!(f, "="),
            Tok::EqEq => write!(f, "=="),
            Tok::Ne => write!(f, "!="),
            Tok::Lt => write!(f, "<"),
            Tok::Le => write!(f, "<="),
            Tok::Gt => write!(f, ">"),
            Tok::Ge => write!(f, ">="),
            Tok::Plus => write!(f, "+"),
            Tok::Minus => write!(f, "-"),
            Tok::Star => write!(f, "*"),
            Tok::Slash => write!(f, "/"),
            Tok::Dot => write!(f, "."),
            Tok::Newline => write!(f, "\\n"),
            Tok::Eof => write!(f, "<eof>"),
        }
    }
}

/// A token with its source line (1-based) for error reporting.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpannedTok {
    pub tok: Tok,
    pub line: usize,
}
