//! LLM-TL: the paper's "LLM-friendly Thinking Language".
//!
//! TL abstracts the execution of an operator on a GPU into two statement
//! families — `Copy` (data movement between global / shared / register
//! memory) and `Compute` (GEMM, softmax, elementwise) — plus the support
//! statements the paper's stage-2 reasoning adds: `Allocate` (tensor
//! declaration at a memory level), `Reshape` (mma fragment-layout change
//! required to fuse consecutive GEMMs), `for` loops and `if` guards.
//!
//! This module is the language core: token stream ([`lexer`]), symbolic
//! dimension expressions ([`expr`]), AST ([`ast`]), recursive-descent
//! parser ([`parser`]) and pretty-printer ([`printer`]). The printer and
//! parser round-trip: `parse(print(p)) == p` (property-tested).

pub mod ast;
pub mod error;
pub mod expr;
pub mod lexer;
pub mod parser;
pub mod printer;
pub mod token;
pub mod types;

pub use ast::{ComputeOp, Stmt, TensorRef, TlProgram};
pub use error::TlError;
pub use expr::Expr;
pub use parser::parse_program;
pub use printer::print_program;
pub use types::{DType, Frag, Layout, MemSpace};
