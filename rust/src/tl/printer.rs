//! Pretty-printer for TL programs. `parse_program(print_program(p))`
//! round-trips (property-tested in `rust/tests/tl_roundtrip.rs`).

use super::ast::{Stmt, TensorRef, TlProgram};
use std::fmt::Write;

pub fn print_program(p: &TlProgram) -> String {
    let mut out = String::new();
    for s in &p.stmts {
        print_stmt(&mut out, s, 0);
    }
    out
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn tensor_ref(t: &TensorRef) -> String {
    if t.transposed {
        format!("{}.T", t.name)
    } else {
        t.name.clone()
    }
}

fn print_stmt(out: &mut String, s: &Stmt, depth: usize) {
    indent(out, depth);
    match s {
        Stmt::Param { name, value } => {
            writeln!(out, "param {name} = {value}").unwrap();
        }
        Stmt::Allocate { name, space, shape, offset, dtype } => {
            let dims: Vec<String> = shape.iter().map(|e| e.to_string()).collect();
            write!(out, "Allocate {name} in {space} ({})", dims.join(", ")).unwrap();
            if let Some(off) = offset {
                write!(out, " with offset {off}").unwrap();
            }
            if let Some(d) = dtype {
                write!(out, " as {d}").unwrap();
            }
            out.push('\n');
        }
        Stmt::Copy { tensor, shape, coord, src, dst } => {
            write!(out, "Copy {tensor}").unwrap();
            if let Some(shape) = shape {
                let dims: Vec<String> = shape.iter().map(|e| e.to_string()).collect();
                write!(out, " ({})", dims.join(", ")).unwrap();
            }
            if !coord.is_empty() {
                let cs: Vec<String> =
                    coord.iter().map(|(n, e)| format!("{n} = {e}")).collect();
                write!(out, " in coordinate [{}]", cs.join(", ")).unwrap();
            }
            writeln!(out, " from {src} to {dst}").unwrap();
        }
        Stmt::Compute { op, inputs, coord, with, output, accumulate, new_var } => {
            write!(out, "Compute {}", op.as_str()).unwrap();
            let ins: Vec<String> = inputs.iter().map(tensor_ref).collect();
            if !ins.is_empty() {
                write!(out, " {}", ins.join(", ")).unwrap();
            }
            if !coord.is_empty() {
                let cs: Vec<String> =
                    coord.iter().map(|(n, e)| format!("{n} = {e}")).collect();
                write!(out, " in coordinate [{}]", cs.join(", ")).unwrap();
            }
            if !with.is_empty() {
                // Paper style: `with a and b` for two names, commas before
                // the final `and` for longer lists.
                if with.len() == 1 {
                    write!(out, " with {}", with[0]).unwrap();
                } else {
                    let head = &with[..with.len() - 1];
                    write!(out, " with {} and {}", head.join(", "), with.last().unwrap())
                        .unwrap();
                }
            }
            if let Some(o) = output {
                if *accumulate {
                    write!(out, " and accumulate {o}").unwrap();
                } else if *new_var {
                    write!(out, " and get new {o}").unwrap();
                } else {
                    write!(out, " and get {o}").unwrap();
                }
            }
            out.push('\n');
        }
        Stmt::Reshape { tensor, from, to } => {
            writeln!(out, "Reshape {tensor} from {from} to {to}").unwrap();
        }
        Stmt::For { var, start, end, body } => {
            writeln!(out, "for {var} = {start}:{end}").unwrap();
            for s in body {
                print_stmt(out, s, depth + 1);
            }
            indent(out, depth);
            out.push_str("end\n");
        }
        Stmt::If { lhs, op, rhs, body } => {
            writeln!(out, "if {lhs} {} {rhs}", op.as_str()).unwrap();
            for s in body {
                print_stmt(out, s, depth + 1);
            }
            indent(out, depth);
            out.push_str("end\n");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tl::parser::parse_program;

    fn roundtrip(src: &str) {
        let p1 = parse_program(src).unwrap();
        let printed = print_program(&p1);
        let p2 = parse_program(&printed).unwrap();
        assert_eq!(p1.stmts, p2.stmts, "roundtrip failed for:\n{src}\nprinted:\n{printed}");
    }

    #[test]
    fn roundtrip_copy_variants() {
        roundtrip("Copy Q from global to shared");
        roundtrip("Copy Q (BM, HeadDim) in coordinate [L = block_idx] from global to shared");
        roundtrip("Copy O from register to global");
    }

    #[test]
    fn roundtrip_gather_coordinates() {
        roundtrip("Copy K (BN, HeadDim) in coordinate [L = block_table[i]] from global to shared");
        roundtrip(
            "Copy V (BN, VDim) in coordinate [H = head_idx / group_size, L = block_table[i + 1]] from global to shared",
        );
        roundtrip("Compute WindowMask S in coordinate [Lq = block_idx, Lk = i]");
    }

    #[test]
    fn roundtrip_compute_variants() {
        roundtrip("Compute GEMM Q, K.T and get S");
        roundtrip("Compute GEMM S, V and accumulate O");
        roundtrip("Compute Softmax S with m and l");
        roundtrip("Compute Softmax S with m, l and acc");
        roundtrip("Compute Multiply A, x and get new A");
        roundtrip("Compute CausalMask S in coordinate [Lq = bi, Lk = i]");
    }

    #[test]
    fn roundtrip_structured() {
        roundtrip(
            "param BM = 64\nAllocate O in register (BM, HeadDim)\nfor i = 0:kv_len/BN\n  if i < kv_len/BN - 1\n    Copy K (BN, HeadDim) in coordinate [L = i + 1] from global to shared\n  end\n  Compute Softmax S with m and l\nend\n",
        );
    }

    #[test]
    fn roundtrip_reshape() {
        roundtrip("Reshape G from (MMA_C, MMA_M, MMA_N) to (MMA_A, MMA_M, MMA_N_new)");
        roundtrip("Reshape rS from mma_C to mma_A");
    }

    #[test]
    fn print_indented_blocks() {
        let src = "for i = 0:4\n  Compute Softmax S\nend\n";
        let p = parse_program(src).unwrap();
        assert_eq!(print_program(&p), "for i = 0:4\n  Compute Softmax S\nend\n");
    }
}
