//! Workload generation: the paper's benchmark grids (§4.1) for the
//! performance model, the real-model configurations of Appendix C, and
//! synthetic request streams for the serving coordinator.

use crate::coordinator::request::FamilyKey;
use crate::sketch::spec::{AttnVariant, OpSpec};
use crate::util::prng::Rng;

/// The paper's sequence-length sweep: 512, 1k, ..., 16k.
pub const SEQ_SWEEP: [usize; 6] = [512, 1024, 2048, 4096, 8192, 16384];

/// Table-1 grid: {MHA, GQA, MQA} × {64, 128} × sweep × {causal, full}.
pub fn table1_grid(causal: bool) -> Vec<OpSpec> {
    let mut specs = Vec::new();
    for variant in [AttnVariant::Mha, AttnVariant::Gqa, AttnVariant::Mqa] {
        for head_dim in [64usize, 128] {
            for seq in SEQ_SWEEP {
                specs.push(OpSpec::benchmark(variant, seq, head_dim, causal));
            }
        }
    }
    specs
}

/// Table-2 grid: MLA with causal mask across the sweep.
pub fn table2_grid() -> Vec<OpSpec> {
    SEQ_SWEEP.iter().map(|&s| OpSpec::mla(s, true)).collect()
}

/// Appendix C / Table 8: production model configurations (all head-dim
/// 128, causal).
pub fn real_models() -> Vec<(String, Vec<OpSpec>)> {
    let configs = [
        ("Llama2 7B", 32usize, 32usize),
        ("Qwen2.5 72B", 64, 8),
        ("Llama3.1 405B", 128, 8),
    ];
    configs
        .iter()
        .map(|(name, hq, hk)| {
            let specs = SEQ_SWEEP
                .iter()
                .map(|&s| OpSpec::real_model(name, *hq, *hk, s).1)
                .collect();
            (name.to_string(), specs)
        })
        .collect()
}

/// Table-9 grid: NSA latency sweep.
pub fn nsa_grid() -> Vec<OpSpec> {
    SEQ_SWEEP.iter().map(|&s| OpSpec::nsa(s)).collect()
}

/// A synthetic request for the serving coordinator: family + seeded
/// payload (materialized lazily to keep generation cheap).
#[derive(Debug, Clone)]
pub struct SyntheticRequest {
    pub family: FamilyKey,
    pub seed: u64,
    /// Offset from stream start (exponential inter-arrival).
    pub arrival: std::time::Duration,
}

impl SyntheticRequest {
    /// Materialize Q/K/V payloads.
    pub fn payload(&self) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(self.seed);
        let gen = |n: usize, rng: &mut Rng| -> Vec<f32> {
            (0..n).map(|_| rng.normal() as f32 * 0.5).collect()
        };
        let q = gen(self.family.q_len(), &mut rng);
        let k = gen(self.family.k_len(), &mut rng);
        let v = gen(self.family.v_len(), &mut rng);
        (q, k, v)
    }
}

/// Generate a Poisson-ish request stream over the servable families.
///
/// `rate_hz` is the target aggregate arrival rate; families are drawn
/// with a skew where the first families get more traffic (realistic
/// serving mixes are head-heavy).
pub fn request_stream(
    families: &[FamilyKey],
    n: usize,
    rate_hz: f64,
    seed: u64,
) -> Vec<SyntheticRequest> {
    assert!(!families.is_empty(), "no servable families");
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        // Exponential inter-arrival: -ln(U)/rate.
        let u = rng.f64().max(1e-12);
        t += -u.ln() / rate_hz;
        // Zipf-ish family choice: squash the uniform draw.
        let idx = ((rng.f64().powi(2)) * families.len() as f64) as usize;
        let family = families[idx.min(families.len() - 1)].clone();
        out.push(SyntheticRequest {
            family,
            seed: seed.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15),
            arrival: std::time::Duration::from_secs_f64(t),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_grid_size() {
        // 3 variants x 2 head dims x 6 seq lens.
        assert_eq!(table1_grid(true).len(), 36);
    }

    #[test]
    fn grids_keep_total_tokens() {
        for spec in table1_grid(true) {
            assert_eq!(spec.batch * spec.seq_len, 16 * 1024);
        }
    }

    #[test]
    fn real_models_match_paper_configs() {
        let models = real_models();
        assert_eq!(models.len(), 3);
        let (name, specs) = &models[1];
        assert_eq!(name, "Qwen2.5 72B");
        assert_eq!(specs[0].num_q_heads, 64);
        assert_eq!(specs[0].num_kv_heads, 8);
        assert_eq!(specs[0].head_dim, 128);
        assert!(specs[0].causal);
    }

    #[test]
    fn request_stream_is_sorted_and_deterministic() {
        let fam = FamilyKey {
            variant: AttnVariant::Mha,
            causal: true,
            qk_dim: 64,
            v_dim: 64,
            q_heads: 4,
            kv_heads: 4,
            seq: 256,
            kv: 256,
        };
        let a = request_stream(&[fam.clone()], 50, 100.0, 7);
        let b = request_stream(&[fam], 50, 100.0, 7);
        assert_eq!(a.len(), 50);
        for w in a.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        assert_eq!(a[10].seed, b[10].seed);
    }

    #[test]
    fn payload_sizes_match_family() {
        let fam = FamilyKey {
            variant: AttnVariant::Gqa,
            causal: true,
            qk_dim: 64,
            v_dim: 64,
            q_heads: 8,
            kv_heads: 2,
            seq: 128,
            kv: 128,
        };
        let r = SyntheticRequest {
            family: fam.clone(),
            seed: 1,
            arrival: std::time::Duration::ZERO,
        };
        let (q, k, v) = r.payload();
        assert_eq!(q.len(), fam.q_len());
        assert_eq!(k.len(), fam.k_len());
        assert_eq!(v.len(), fam.v_len());
    }
}
