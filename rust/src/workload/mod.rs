//! Workload generation: the paper's benchmark grids (§4.1) for the
//! performance model, the real-model configurations of Appendix C, and
//! synthetic request streams (prefill, decode, and seeded mixes) for the
//! serving coordinator.

use crate::coordinator::request::{FamilyKey, LaneKey};
use crate::sketch::spec::{AttnVariant, Direction, KvLayout, OpSpec, ScorePattern};
use crate::util::prng::Rng;

/// The paper's sequence-length sweep: 512, 1k, ..., 16k.
pub const SEQ_SWEEP: [usize; 6] = [512, 1024, 2048, 4096, 8192, 16384];

/// Table-1 grid: {MHA, GQA, MQA} × {64, 128} × sweep × {causal, full}.
pub fn table1_grid(causal: bool) -> Vec<OpSpec> {
    let mut specs = Vec::new();
    for variant in [AttnVariant::Mha, AttnVariant::Gqa, AttnVariant::Mqa] {
        for head_dim in [64usize, 128] {
            for seq in SEQ_SWEEP {
                specs.push(OpSpec::benchmark(variant, seq, head_dim, causal));
            }
        }
    }
    specs
}

/// Table-2 grid: MLA with causal mask across the sweep.
pub fn table2_grid() -> Vec<OpSpec> {
    SEQ_SWEEP.iter().map(|&s| OpSpec::mla(s, true)).collect()
}

/// Backward-pass (training) grid: the causal Table-1 sweep with
/// `direction = Backward` — the specs `tlc tune` and `benches/backward`
/// search/time for gradient kernels.
pub fn backward_grid() -> Vec<OpSpec> {
    table1_grid(true).into_iter().map(|s| s.with_direction(Direction::Backward)).collect()
}

/// Appendix C / Table 8: production model configurations (all head-dim
/// 128, causal).
pub fn real_models() -> Vec<(String, Vec<OpSpec>)> {
    let configs = [
        ("Llama2 7B", 32usize, 32usize),
        ("Qwen2.5 72B", 64, 8),
        ("Llama3.1 405B", 128, 8),
    ];
    configs
        .iter()
        .map(|(name, hq, hk)| {
            let specs = SEQ_SWEEP
                .iter()
                .map(|&s| OpSpec::real_model(name, *hq, *hk, s).1)
                .collect();
            (name.to_string(), specs)
        })
        .collect()
}

/// Table-9 grid: NSA latency sweep.
pub fn nsa_grid() -> Vec<OpSpec> {
    SEQ_SWEEP.iter().map(|&s| OpSpec::nsa(s)).collect()
}

/// A synthetic request for the serving coordinator: family + seeded
/// payload (materialized lazily to keep generation cheap).
#[derive(Debug, Clone)]
pub struct SyntheticRequest {
    pub family: FamilyKey,
    pub seed: u64,
    /// Offset from stream start (exponential inter-arrival).
    pub arrival: std::time::Duration,
    /// Shared-prefix group: `(prefix_seed, prefix_rows)`. The first
    /// `prefix_rows` K/V rows of every head are drawn from a group-wide
    /// stream, so every request carrying the same pair materializes
    /// bit-identical prefix content (what the COW prefix cache dedups).
    pub prefix: Option<(u64, usize)>,
}

impl SyntheticRequest {
    /// Materialize Q/K/V payloads.
    pub fn payload(&self) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(self.seed);
        let gen = |n: usize, rng: &mut Rng| -> Vec<f32> {
            (0..n).map(|_| rng.normal() as f32 * 0.5).collect()
        };
        let q = gen(self.family.q_len(), &mut rng);
        match self.prefix {
            // No prefix group: the draw order below is byte-identical to
            // what this generator always produced (seeded streams stay
            // reproducible across the prefix-cache change).
            None => {
                let k = gen(self.family.k_len(), &mut rng);
                let v = gen(self.family.v_len(), &mut rng);
                (q, k, v)
            }
            Some((prefix_seed, prefix_rows)) => {
                let prows = prefix_rows.min(self.family.kv);
                let mut prng = Rng::new(prefix_seed);
                let (heads, kv) = (self.family.kv_heads, self.family.kv);
                let mut build = |dim: usize| -> Vec<f32> {
                    // Head-major [kv_heads][kv][dim]: shared rows come
                    // from the group stream, the tail from the request
                    // stream. Draw order is fixed per family shape, so
                    // fan-out members produce identical prefixes.
                    let mut out = Vec::with_capacity(heads * kv * dim);
                    for _ in 0..heads {
                        for r in 0..kv {
                            let src =
                                if r < prows { &mut prng } else { &mut rng };
                            for _ in 0..dim {
                                out.push(src.normal() as f32 * 0.5);
                            }
                        }
                    }
                    out
                };
                let k = build(self.family.qk_dim);
                let v = build(self.family.v_dim);
                (q, k, v)
            }
        }
    }
}

/// Generate a Poisson-ish request stream over the servable families.
///
/// `rate_hz` is the target aggregate arrival rate; families are drawn
/// with a skew where the first families get more traffic (realistic
/// serving mixes are head-heavy).
pub fn request_stream(
    families: &[FamilyKey],
    n: usize,
    rate_hz: f64,
    seed: u64,
) -> Vec<SyntheticRequest> {
    assert!(!families.is_empty(), "no servable families");
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        // Exponential inter-arrival: -ln(U)/rate.
        let u = rng.f64().max(1e-12);
        t += -u.ln() / rate_hz;
        // Zipf-ish family choice: squash the uniform draw.
        let idx = ((rng.f64().powi(2)) * families.len() as f64) as usize;
        let family = families[idx.min(families.len() - 1)].clone();
        out.push(SyntheticRequest {
            family,
            seed: seed.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15),
            arrival: std::time::Duration::from_secs_f64(t),
            prefix: None,
        });
    }
    out
}

/// The decode-shaped twin of a prefill family: one query row attending
/// the whole KV cache. Non-causal — in autoregressive decode the entire
/// cache *is* the past, so the mask is trivially all-visible (and the
/// repo's reference oracle aligns its causal mask top-left, which would
/// be wrong for a bottom-row query).
pub fn decode_twin(f: &FamilyKey) -> FamilyKey {
    FamilyKey {
        causal: false,
        seq: 1,
        kv: f.kv.max(f.seq).max(4), // LaneKey::of needs kv >= 4*seq
        ..f.clone()
    }
}

/// Families served by the reference executor when no AOT manifest is
/// compiled: a small cross-variant prefill set plus decode twins. Kept
/// at seq 64 so the CPU oracle stays O(ms) per request even in debug
/// builds (the scheduler tests serve dozens of these).
pub fn reference_serving_families() -> Vec<FamilyKey> {
    reference_serving_families_layout(KvLayout::Contiguous)
}

/// [`reference_serving_families`] with the decode twins carrying the
/// given KV layout — `tlc serve --kv-layout paged` points the decode
/// lane (and its KV pool accounting) at paged/sliding families while
/// prefill stays contiguous.
pub fn reference_serving_families_layout(decode_layout: KvLayout) -> Vec<FamilyKey> {
    let mut fams = Vec::new();
    for (variant, q_heads, kv_heads) in
        [(AttnVariant::Mha, 4, 4), (AttnVariant::Gqa, 8, 2), (AttnVariant::Mqa, 4, 1)]
    {
        let f = FamilyKey {
            variant,
            causal: true,
            qk_dim: 64,
            v_dim: 64,
            q_heads,
            kv_heads,
            seq: 64,
            kv: 64,
            kv_layout: KvLayout::Contiguous,
            direction: Direction::Forward,
            pattern: ScorePattern::Dense,
        };
        let mut d = decode_twin(&f);
        d.kv_layout = decode_layout;
        fams.push(d);
        fams.push(f);
    }
    fams
}

/// Seeded paged-decode request stream: decode-shaped families over a
/// paged KV cache (`page_size`-row pages), Poisson arrivals with a
/// head-heavy family mix — the canonical traffic for the decode lane's
/// paged KV pool. Deterministic per seed.
pub fn paged_decode_stream(
    n: usize,
    rate_hz: f64,
    page_size: usize,
    max_kv: usize,
    seed: u64,
) -> Vec<SyntheticRequest> {
    let mut fams = Vec::new();
    for (variant, q_heads, kv_heads) in
        [(AttnVariant::Mha, 4, 4), (AttnVariant::Gqa, 8, 2), (AttnVariant::Mqa, 4, 1)]
    {
        for kv in [256usize, 1024, 4096] {
            if kv > max_kv {
                continue;
            }
            fams.push(FamilyKey {
                variant,
                causal: false, // one decode row attends the whole cache
                qk_dim: 64,
                v_dim: 64,
                q_heads,
                kv_heads,
                seq: 1,
                kv,
                kv_layout: KvLayout::Paged { page_size },
                direction: Direction::Forward,
                pattern: ScorePattern::Dense,
            });
        }
    }
    assert!(!fams.is_empty(), "max_kv clamps away every paged decode shape");
    request_stream_mixed(&fams, n, rate_hz, 1.0, seed)
}

/// Generate a Poisson-ish stream with a seeded prefill/decode mix:
/// each arrival is a decode-lane request with probability `decode_frac`
/// (drawn from the decode-shaped members of `families`), otherwise a
/// prefill request. Falls back gracefully when a lane has no families.
pub fn request_stream_mixed(
    families: &[FamilyKey],
    n: usize,
    rate_hz: f64,
    decode_frac: f64,
    seed: u64,
) -> Vec<SyntheticRequest> {
    assert!(!families.is_empty(), "no servable families");
    let decode: Vec<&FamilyKey> =
        families.iter().filter(|f| LaneKey::of(f) == LaneKey::Decode).collect();
    let prefill: Vec<&FamilyKey> =
        families.iter().filter(|f| LaneKey::of(f) == LaneKey::Prefill).collect();
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let u = rng.f64().max(1e-12);
        t += -u.ln() / rate_hz;
        let lane_pool: &[&FamilyKey] = if !decode.is_empty()
            && (prefill.is_empty() || rng.f64() < decode_frac)
        {
            &decode
        } else {
            &prefill
        };
        // Zipf-ish family choice within the lane (head-heavy mixes).
        let idx = ((rng.f64().powi(2)) * lane_pool.len() as f64) as usize;
        let family = lane_pool[idx.min(lane_pool.len() - 1)].clone();
        out.push(SyntheticRequest {
            family,
            seed: seed.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15),
            arrival: std::time::Duration::from_secs_f64(t),
            prefix: None,
        });
    }
    out
}

/// Bursty stream for the fault-injection harness: arrivals alternate
/// between calm stretches at `rate_hz` and bursts at `burst_factor ×`
/// that rate (geometric phase lengths), so deadline shedding and retry
/// backoff are exercised under realistic load spikes instead of a
/// smooth Poisson process. Deterministic per seed; same family mix as
/// [`request_stream_mixed`].
pub fn fault_stream(
    families: &[FamilyKey],
    n: usize,
    rate_hz: f64,
    burst_factor: f64,
    decode_frac: f64,
    seed: u64,
) -> Vec<SyntheticRequest> {
    assert!(!families.is_empty(), "no servable families");
    assert!(burst_factor >= 1.0, "burst_factor must be >= 1");
    let decode: Vec<&FamilyKey> =
        families.iter().filter(|f| LaneKey::of(f) == LaneKey::Decode).collect();
    let prefill: Vec<&FamilyKey> =
        families.iter().filter(|f| LaneKey::of(f) == LaneKey::Prefill).collect();
    let mut rng = Rng::new(seed ^ 0xFA17);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n);
    let mut bursting = false;
    let mut phase_left = 0usize;
    for i in 0..n {
        if phase_left == 0 {
            // Geometric phase lengths: bursts are short (mean 8
            // requests), calm stretches longer (mean 24).
            bursting = !bursting;
            let mean = if bursting { 8.0 } else { 24.0 };
            phase_left = 1 + (-(rng.f64().max(1e-12)).ln() * mean) as usize;
        }
        phase_left -= 1;
        let rate = if bursting { rate_hz * burst_factor } else { rate_hz };
        let u = rng.f64().max(1e-12);
        t += -u.ln() / rate;
        let lane_pool: &[&FamilyKey] = if !decode.is_empty()
            && (prefill.is_empty() || rng.f64() < decode_frac)
        {
            &decode
        } else {
            &prefill
        };
        let idx = ((rng.f64().powi(2)) * lane_pool.len() as f64) as usize;
        let family = lane_pool[idx.min(lane_pool.len() - 1)].clone();
        out.push(SyntheticRequest {
            family,
            seed: seed.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15),
            arrival: std::time::Duration::from_secs_f64(t),
            prefix: None,
        });
    }
    out
}

/// Decode-only stream over the Appendix-C / Table-8 production configs:
/// each model contributes decode families (one query row over a KV cache
/// drawn from the paper's sweep, clamped to `max_kv` so host payloads
/// stay bounded). This is what points the decode lane at real-model
/// shapes.
pub fn real_model_decode_stream(
    n: usize,
    rate_hz: f64,
    max_kv: usize,
    seed: u64,
) -> Vec<SyntheticRequest> {
    let mut fams = Vec::new();
    for (_, specs) in real_models() {
        for spec in specs {
            if spec.kv_len > max_kv {
                continue;
            }
            fams.push(FamilyKey {
                variant: spec.variant,
                causal: false,
                qk_dim: spec.qk_dim(),
                v_dim: spec.v_head_dim,
                q_heads: spec.num_q_heads,
                kv_heads: spec.num_kv_heads,
                seq: 1,
                kv: spec.kv_len,
                kv_layout: spec.kv_layout,
                direction: spec.direction,
                pattern: spec.pattern,
            });
        }
    }
    assert!(!fams.is_empty(), "max_kv clamps away every Table-8 config");
    request_stream_mixed(&fams, n, rate_hz, 1.0, seed)
}

/// Shared-prefix decode traffic for the continuous-batching bench: each
/// of `n_prefixes` groups is a distinct paged GQA decode family whose
/// `fanout` members share the *entire* K/V cache (bit-identical pages)
/// while carrying unique Q rows — the many-completions-per-prompt shape
/// the COW prefix cache exists for. Arrivals are all-at-once; the bench
/// submits the stream in a tight loop and measures admitted QPS.
pub fn shared_prefix_stream(
    n_prefixes: usize,
    fanout: usize,
    seed: u64,
) -> Vec<SyntheticRequest> {
    assert!(n_prefixes > 0 && fanout > 0, "empty shared-prefix stream");
    let page_size = 16usize;
    let mut out = Vec::with_capacity(n_prefixes * fanout);
    for g in 0..n_prefixes {
        // Distinct KV length per group keeps the families (and hence the
        // radix-tree roots) distinct while staying page-aligned.
        let kv = 512 + page_size * g;
        let family = FamilyKey {
            variant: AttnVariant::Gqa,
            causal: false,
            qk_dim: 64,
            v_dim: 64,
            q_heads: 8,
            kv_heads: 2,
            seq: 1,
            kv,
            kv_layout: KvLayout::Paged { page_size },
            direction: Direction::Forward,
            pattern: ScorePattern::Dense,
        };
        let prefix_seed =
            seed ^ (0xA5A5_0000u64 + g as u64).wrapping_mul(0x9E3779B97F4A7C15);
        for f in 0..fanout {
            let i = (g * fanout + f) as u64;
            out.push(SyntheticRequest {
                family: family.clone(),
                seed: seed.wrapping_add(i).wrapping_mul(0x9E3779B97F4A7C15),
                arrival: std::time::Duration::ZERO,
                prefix: Some((prefix_seed, kv)),
            });
        }
    }
    out
}

/// Mixed score-pattern decode traffic: one base decode shape served
/// under all three [`ScorePattern`]s (dense, block-sparse top-k,
/// window+global). The three families share every shape field and
/// differ only in pattern (and the causality window+global implies), so
/// a stream over them exercises per-pattern family isolation in the
/// router/batcher, the pattern-clipped KV-residency accounting of
/// [`FamilyKey::kv_bytes`], and per-pattern outcome bookkeeping under
/// fault injection. Poisson arrivals, head-heavy mix, deterministic per
/// seed.
pub fn mixed_pattern_stream(n: usize, rate_hz: f64, seed: u64) -> Vec<SyntheticRequest> {
    let base = FamilyKey {
        variant: AttnVariant::Gqa,
        causal: false,
        qk_dim: 64,
        v_dim: 64,
        q_heads: 8,
        kv_heads: 2,
        seq: 1,
        kv: 1024,
        kv_layout: KvLayout::Contiguous,
        direction: Direction::Forward,
        pattern: ScorePattern::Dense,
    };
    let fams = vec![
        base.clone(),
        FamilyKey {
            pattern: ScorePattern::BlockSparse { block: 64, topk: 4 },
            ..base.clone()
        },
        FamilyKey {
            causal: true, // window+global implies a causal sweep
            pattern: ScorePattern::WindowGlobal { window: 256, n_global: 64 },
            ..base
        },
    ];
    request_stream_mixed(&fams, n, rate_hz, 1.0, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_grid_size() {
        // 3 variants x 2 head dims x 6 seq lens.
        assert_eq!(table1_grid(true).len(), 36);
    }

    #[test]
    fn grids_keep_total_tokens() {
        for spec in table1_grid(true) {
            assert_eq!(spec.batch * spec.seq_len, 16 * 1024);
        }
    }

    #[test]
    fn real_models_match_paper_configs() {
        let models = real_models();
        assert_eq!(models.len(), 3);
        let (name, specs) = &models[1];
        assert_eq!(name, "Qwen2.5 72B");
        assert_eq!(specs[0].num_q_heads, 64);
        assert_eq!(specs[0].num_kv_heads, 8);
        assert_eq!(specs[0].head_dim, 128);
        assert!(specs[0].causal);
    }

    #[test]
    fn request_stream_is_sorted_and_deterministic() {
        let fam = FamilyKey {
            variant: AttnVariant::Mha,
            causal: true,
            qk_dim: 64,
            v_dim: 64,
            q_heads: 4,
            kv_heads: 4,
            seq: 256,
            kv: 256,
            kv_layout: KvLayout::Contiguous,
            direction: Direction::Forward,
            pattern: ScorePattern::Dense,
        };
        let a = request_stream(&[fam.clone()], 50, 100.0, 7);
        let b = request_stream(&[fam], 50, 100.0, 7);
        assert_eq!(a.len(), 50);
        for w in a.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        assert_eq!(a[10].seed, b[10].seed);
    }

    #[test]
    fn mixed_stream_respects_decode_frac_and_seed() {
        let fams = reference_serving_families();
        assert!(fams.iter().any(|f| LaneKey::of(f) == LaneKey::Decode));
        assert!(fams.iter().any(|f| LaneKey::of(f) == LaneKey::Prefill));
        let a = request_stream_mixed(&fams, 200, 500.0, 0.5, 9);
        let b = request_stream_mixed(&fams, 200, 500.0, 0.5, 9);
        assert_eq!(
            a.iter().map(|r| r.family.clone()).collect::<Vec<_>>(),
            b.iter().map(|r| r.family.clone()).collect::<Vec<_>>(),
            "same seed, same mix"
        );
        let decode = a.iter().filter(|r| LaneKey::of(&r.family) == LaneKey::Decode).count();
        assert!((40..=160).contains(&decode), "≈50% decode, got {decode}/200");
        // Extremes collapse to a single lane.
        let none = request_stream_mixed(&fams, 50, 500.0, 0.0, 9);
        assert!(none.iter().all(|r| LaneKey::of(&r.family) == LaneKey::Prefill));
        let all = request_stream_mixed(&fams, 50, 500.0, 1.0, 9);
        assert!(all.iter().all(|r| LaneKey::of(&r.family) == LaneKey::Decode));
    }

    #[test]
    fn decode_twin_is_decode_shaped() {
        for f in reference_serving_families() {
            let d = decode_twin(&f);
            assert_eq!(LaneKey::of(&d), LaneKey::Decode);
            assert_eq!(d.q_len(), f.q_heads * f.qk_dim, "one query row");
        }
    }

    #[test]
    fn paged_decode_stream_is_decode_lane_paged_and_seeded() {
        let a = paged_decode_stream(60, 1000.0, 16, 4096, 11);
        let b = paged_decode_stream(60, 1000.0, 16, 4096, 11);
        assert_eq!(a.len(), 60);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.family, y.family, "same seed, same stream");
        }
        for r in &a {
            assert_eq!(LaneKey::of(&r.family), LaneKey::Decode);
            assert_eq!(r.family.kv_layout, KvLayout::Paged { page_size: 16 });
        }
        // Clamp keeps only the small cache.
        let small = paged_decode_stream(20, 1000.0, 16, 256, 11);
        assert!(small.iter().all(|r| r.family.kv <= 256));
    }

    #[test]
    fn layouted_reference_families_only_touch_decode_twins() {
        let fams = reference_serving_families_layout(KvLayout::Sliding { window: 32 });
        for f in &fams {
            match LaneKey::of(f) {
                LaneKey::Decode => {
                    assert_eq!(f.kv_layout, KvLayout::Sliding { window: 32 })
                }
                LaneKey::Prefill => assert_eq!(f.kv_layout, KvLayout::Contiguous),
            }
        }
    }

    #[test]
    fn real_model_decode_stream_matches_table8_heads() {
        let stream = real_model_decode_stream(40, 1000.0, 2048, 3);
        assert_eq!(stream.len(), 40);
        for r in &stream {
            assert_eq!(LaneKey::of(&r.family), LaneKey::Decode);
            assert_eq!(r.family.qk_dim, 128, "Appendix C is head-dim 128");
            assert!(r.family.kv <= 2048);
            assert!(
                [(32, 32), (64, 8), (128, 8)]
                    .contains(&(r.family.q_heads, r.family.kv_heads)),
                "unexpected head config {:?}",
                (r.family.q_heads, r.family.kv_heads)
            );
        }
    }

    #[test]
    fn fault_stream_is_deterministic_and_bursty() {
        let fams = reference_serving_families();
        let a = fault_stream(&fams, 300, 200.0, 8.0, 0.5, 13);
        let b = fault_stream(&fams, 300, 200.0, 8.0, 0.5, 13);
        assert_eq!(a.len(), 300);
        assert_eq!(
            a.iter().map(|r| (r.family.clone(), r.arrival)).collect::<Vec<_>>(),
            b.iter().map(|r| (r.family.clone(), r.arrival)).collect::<Vec<_>>(),
            "same seed, same stream"
        );
        for w in a.windows(2) {
            assert!(w[0].arrival <= w[1].arrival, "arrivals sorted");
        }
        // Bursty: the inter-arrival spread is far wider than a smooth
        // Poisson process at the same mean rate — the shortest gaps
        // (inside bursts) are much tighter than the longest (calm).
        let gaps: Vec<f64> = a
            .windows(2)
            .map(|w| (w[1].arrival - w[0].arrival).as_secs_f64())
            .collect();
        let min = gaps.iter().cloned().fold(f64::MAX, f64::min);
        let max = gaps.iter().cloned().fold(0.0f64, f64::max);
        assert!(max > 8.0 * min.max(1e-9), "burst/calm gap spread: {min} .. {max}");
    }

    #[test]
    fn payload_sizes_match_family() {
        let fam = FamilyKey {
            variant: AttnVariant::Gqa,
            causal: true,
            qk_dim: 64,
            v_dim: 64,
            q_heads: 8,
            kv_heads: 2,
            seq: 128,
            kv: 128,
            kv_layout: KvLayout::Contiguous,
            direction: Direction::Forward,
            pattern: ScorePattern::Dense,
        };
        let r = SyntheticRequest {
            family: fam.clone(),
            seed: 1,
            arrival: std::time::Duration::ZERO,
            prefix: None,
        };
        let (q, k, v) = r.payload();
        assert_eq!(q.len(), fam.q_len());
        assert_eq!(k.len(), fam.k_len());
        assert_eq!(v.len(), fam.v_len());
    }

    #[test]
    fn shared_prefix_groups_share_kv_bitwise_with_unique_q() {
        let stream = shared_prefix_stream(3, 4, 17);
        assert_eq!(stream.len(), 12);
        for group in stream.chunks(4) {
            let (q0, k0, v0) = group[0].payload();
            assert_eq!(k0.len(), group[0].family.k_len());
            for member in &group[1..] {
                assert_eq!(member.family, group[0].family);
                let (q, k, v) = member.payload();
                assert_eq!(k, k0, "fan-out members share K bitwise");
                assert_eq!(v, v0, "fan-out members share V bitwise");
                assert_ne!(q, q0, "each member carries a unique Q");
            }
        }
        // Distinct groups carry distinct families and distinct caches.
        assert_ne!(stream[0].family, stream[4].family);
        assert_ne!(stream[0].payload().1, stream[4].payload().1);
        // Determinism per seed.
        let again = shared_prefix_stream(3, 4, 17);
        assert_eq!(stream[5].payload(), again[5].payload());
    }

    #[test]
    fn mixed_pattern_stream_covers_all_three_patterns() {
        let a = mixed_pattern_stream(120, 500.0, 21);
        let b = mixed_pattern_stream(120, 500.0, 21);
        assert_eq!(
            a.iter().map(|r| r.family.clone()).collect::<Vec<_>>(),
            b.iter().map(|r| r.family.clone()).collect::<Vec<_>>(),
            "same seed, same stream"
        );
        let mut seen = std::collections::BTreeSet::new();
        for r in &a {
            assert_eq!(LaneKey::of(&r.family), LaneKey::Decode);
            seen.insert(r.family.pattern);
        }
        assert_eq!(seen.len(), 3, "dense, block-sparse and window+global all present");
        // Sparse members pin fewer KV bytes than the dense member.
        let dense = a.iter().find(|r| r.family.pattern == ScorePattern::Dense).unwrap();
        for r in &a {
            if r.family.pattern != ScorePattern::Dense {
                assert!(r.family.kv_bytes() < dense.family.kv_bytes());
            }
        }
    }

    #[test]
    fn prefixless_payload_is_unchanged_by_the_prefix_field() {
        // The prefix-less draw order must stay byte-identical to the
        // historical generator: Q then K then V from one seeded stream.
        let fam = reference_serving_families().remove(0);
        let r = SyntheticRequest {
            family: fam.clone(),
            seed: 99,
            arrival: std::time::Duration::ZERO,
            prefix: None,
        };
        let mut rng = Rng::new(99);
        let mut draw = |n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.normal() as f32 * 0.5).collect()
        };
        let expect_q = draw(fam.q_len());
        let expect_k = draw(fam.k_len());
        let expect_v = draw(fam.v_len());
        assert_eq!(r.payload(), (expect_q, expect_k, expect_v));
    }
}
