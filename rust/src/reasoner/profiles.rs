//! "LLM" profiles — the generator personalities of the paper's ablations.
//!
//! The paper validates LLM-TL with GPT-4o, Claude 3.5, DeepSeek-V3 and
//! DeepSeek-R1 (Table 3) and shows two failure classes when the two-stage
//! generation is collapsed into one (Appendix B). In this reproduction the
//! LLM is replaced by a deterministic rule engine (DESIGN.md §2); a
//! profile selects which rules fire, reproducing the observable
//! differences between models:
//!
//! * **DeepSeek-R1** — longest reasoning: cost-model tile search and
//!   double-buffered prefetch (best Table-3 numbers).
//! * **DeepSeek-V3** — heuristic tiles, prefetch on.
//! * **Claude 3.5** — heuristic tiles, no prefetch (slightly lower).
//! * **GPT-4o** — generates TL but fails CuTe translation ("-" rows in
//!   Table 3; its training corpus predates CuTe maturity).
//! * **GPT-4o + DeepSeek-V3** — GPT-4o's TL handed to V3's backend.
//! * **single-stage** — the Appendix-B ablation: skipping the sketch makes
//!   the generator omit the fusion `Reshape` (Listing 1) or drop the
//!   formal transpose (Listing 2); the verifier must reject both.

use super::tiling::TilingStrategy;

/// Injected defect for the single-stage ablation (Appendix B).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureMode {
    /// Listing 1: no `Reshape` between the fused GEMMs.
    ReshapeOmission,
    /// Listing 2: `Compute GEMM Q, K and get S` — formal `.T` dropped.
    GemmLayoutError,
}

#[derive(Debug, Clone, PartialEq)]
pub struct LlmProfile {
    pub name: &'static str,
    pub tiling: TilingStrategy,
    /// Emit the guarded next-tile prefetch (Listing 1 style) and assume
    /// double-buffered staging.
    pub prefetch: bool,
    /// Whether this model can perform stage-2 translation itself.
    pub can_translate: bool,
    /// Single-stage ablation defect, if any.
    pub failure: Option<FailureMode>,
}

impl LlmProfile {
    pub fn deepseek_r1() -> Self {
        LlmProfile {
            name: "DeepSeek-R1",
            tiling: TilingStrategy::CostSearch,
            prefetch: true,
            can_translate: true,
            failure: None,
        }
    }

    pub fn deepseek_v3() -> Self {
        LlmProfile {
            name: "DeepSeek-V3",
            tiling: TilingStrategy::Heuristic,
            prefetch: true,
            can_translate: true,
            failure: None,
        }
    }

    pub fn claude35() -> Self {
        LlmProfile {
            name: "Claude-3.5",
            tiling: TilingStrategy::Heuristic,
            prefetch: false,
            can_translate: true,
            failure: None,
        }
    }

    pub fn gpt4o() -> Self {
        LlmProfile {
            name: "GPT-4o",
            tiling: TilingStrategy::Heuristic,
            prefetch: false,
            can_translate: false,
            failure: None,
        }
    }

    /// GPT-4o generates the TL Code, DeepSeek-V3 handles translation
    /// (Table 3, row 2).
    pub fn gpt4o_plus_v3() -> Self {
        LlmProfile { name: "GPT-4o+DeepSeek-V3", can_translate: true, ..Self::gpt4o() }
    }

    /// Single-stage ablation: same knobs as `base`, plus an injected
    /// Appendix-B defect.
    pub fn single_stage(base: LlmProfile, failure: FailureMode) -> Self {
        LlmProfile { name: "single-stage", failure: Some(failure), ..base }
    }

    pub fn all_table3() -> Vec<Self> {
        vec![
            Self::gpt4o(),
            Self::gpt4o_plus_v3(),
            Self::claude35(),
            Self::deepseek_v3(),
            Self::deepseek_r1(),
        ]
    }

    /// The default generator used everywhere a specific profile is not
    /// under test (the paper's main tables use DeepSeek-V3 + Ours).
    pub fn default_profile() -> Self {
        Self::deepseek_v3()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn r1_uses_search() {
        assert_eq!(LlmProfile::deepseek_r1().tiling, TilingStrategy::CostSearch);
    }

    #[test]
    fn gpt4o_cannot_translate_alone() {
        assert!(!LlmProfile::gpt4o().can_translate);
        assert!(LlmProfile::gpt4o_plus_v3().can_translate);
    }

    #[test]
    fn single_stage_injects_failure() {
        let p = LlmProfile::single_stage(LlmProfile::deepseek_v3(), FailureMode::ReshapeOmission);
        assert_eq!(p.failure, Some(FailureMode::ReshapeOmission));
        assert!(p.prefetch, "base knobs preserved");
    }

    #[test]
    fn table3_has_five_rows() {
        assert_eq!(LlmProfile::all_table3().len(), 5);
    }
}
