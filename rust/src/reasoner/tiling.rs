//! Tile-size selection (the `BM`/`BN` parameters of §3.2.2).
//!
//! The paper's stage-1b prompt walks the LLM through exactly this
//! reasoning: each thread block owns a `(BM, HeadDim)` slice of Q; K/V
//! stream through shared memory in `(BN, HeadDim)` tiles; the tiles must
//! fit the card's shared-memory budget while keeping enough thread blocks
//! resident per SM for latency hiding. Two strategies mirror the LLM
//! ablation (Table 3): a one-shot heuristic (what DeepSeek-V3 / Claude
//! produce) and a small cost-model search (DeepSeek-R1's longer
//! reasoning finds the better configuration).

use crate::perfmodel::gpu::GpuArch;
use crate::sketch::spec::OpSpec;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TilingStrategy {
    /// One-shot rule: BM = 128 for head-dim ≤ 64 else 64, BN = 64, shrink
    /// to fit shared memory.
    Heuristic,
    /// Enumerate candidates, score with an occupancy × pipeline model,
    /// keep the best.
    CostSearch,
    /// Delegate to the [`crate::autotune`] subsystem: full schedule-space
    /// search scored by the analytical cost model (`perfmodel::cost`).
    /// Ignores the `double_buffer` argument of [`choose`] — the winning
    /// candidate decides its own staging depth.
    Autotune,
}

/// A chosen tiling plus the derived footprint/occupancy facts that the
/// verifier, perf model and EXPERIMENTS.md report.
#[derive(Debug, Clone, PartialEq)]
pub struct Tiling {
    pub bm: usize,
    pub bn: usize,
    /// Double-buffered K/V staging (prefetch next tile during GEMM).
    pub double_buffer: bool,
    /// Shared-memory bytes per thread block.
    pub smem_bytes: usize,
    /// Register bytes per thread block (fp32 accumulators).
    pub reg_bytes: usize,
    /// Thread blocks resident per SM under the smem + register limits.
    pub blocks_per_sm: usize,
}

/// Shared-memory footprint of one thread block: Q tile + K/V tiles
/// (x2 when double-buffered), in the operator's element type.
/// Public so the [`crate::autotune`] space pruner reuses the same
/// arithmetic (it generalizes the x2 to an arbitrary stage count).
pub fn smem_bytes(spec: &OpSpec, bm: usize, bn: usize, double_buffer: bool) -> usize {
    let e = spec.dtype.bytes();
    let q = bm * spec.qk_dim() * e;
    let kv = bn * spec.qk_dim() * e + bn * spec.v_head_dim * e;
    q + if double_buffer { 2 * kv } else { kv }
}

/// Register footprint: fp32 accumulator O (BM × VDim), score tile S
/// (BM × BN), softmax stats (2 × BM), spread across the block's threads.
/// The backward holds four score-shaped tiles (S, P, dP, dS) plus the
/// gradient accumulator and the lse/delta rows, so its pressure is
/// correspondingly higher — the same arithmetic prunes the autotune
/// space for backward specs.
pub fn reg_bytes(spec: &OpSpec, bm: usize, bn: usize) -> usize {
    use crate::sketch::spec::Direction;
    if spec.direction == Direction::Backward {
        let acc = bm * spec.qk_dim().max(spec.v_head_dim);
        4 * (acc + 4 * bm * bn + 2 * bm)
    } else {
        4 * (bm * spec.v_head_dim + bm * bn + 2 * bm)
    }
}

/// Thread blocks resident per SM under the smem + register limits
/// (clamped to the hardware cap of 8 we assume throughout).
pub fn occupancy(arch: &GpuArch, smem: usize, regs: usize) -> usize {
    if smem == 0 {
        return 1;
    }
    let by_smem = arch.smem_per_sm / smem.max(1);
    let by_regs = arch.regfile_per_sm / regs.max(1);
    by_smem.min(by_regs).max(1).min(8)
}

/// Score a candidate (higher is better): occupancy for latency hiding,
/// large BM×BN for mma efficiency and amortized softmax, mild penalty for
/// very wide BN at small sequence lengths (tail effects).
fn score(arch: &GpuArch, spec: &OpSpec, bm: usize, bn: usize, db: bool) -> f64 {
    let smem = smem_bytes(spec, bm, bn, db);
    if smem > arch.smem_per_block {
        return f64::NEG_INFINITY;
    }
    if bm > spec.seq_len || bn > spec.kv_len {
        return f64::NEG_INFINITY;
    }
    let occ = occupancy(arch, smem, reg_bytes(spec, bm, bn)) as f64;
    // MXU/TensorCore efficiency grows with tile area but saturates.
    let tile_eff = ((bm * bn) as f64 / (128.0 * 64.0)).min(1.5);
    // Occupancy beyond ~4 blocks/SM stops helping.
    let occ_eff = (occ / 2.0).min(2.0);
    // Softmax (CUDA-core) work amortizes over BN columns per max/sum pass.
    let softmax_amort = (bn as f64 / 64.0).sqrt().min(1.3);
    // Tail waste when the q-block count doesn't fill the grid.
    let q_blocks = spec.seq_len.div_ceil(bm) * spec.num_q_heads * spec.batch;
    let waves = q_blocks as f64 / (arch.sm_count as f64 * occ);
    let tail = if waves < 1.0 { waves } else { (waves / waves.ceil()).max(0.7) };
    tile_eff * occ_eff * softmax_amort * tail * if db { 1.08 } else { 1.0 }
}

/// Round `bn` to a multiple of the paged layout's page size (no-op for
/// other layouts): a KV tile must gather whole pages, so `BN % page == 0`
/// is a hard constraint every tiling chooser applies.
pub fn page_align_bn(spec: &OpSpec, bn: usize) -> usize {
    match spec.kv_layout.page_size() {
        Some(page) if page > 0 => {
            if bn >= page {
                bn - bn % page
            } else {
                // Tiles smaller than a page round up to one page.
                page.min(spec.kv_len.max(1))
            }
        }
        _ => bn,
    }
}

/// Choose tile sizes for `spec` on `arch`.
pub fn choose(
    strategy: TilingStrategy,
    spec: &OpSpec,
    arch: &GpuArch,
    double_buffer: bool,
) -> Tiling {
    let (bm, bn) = match strategy {
        TilingStrategy::Autotune => {
            // Full schedule-space search; the candidate carries its own
            // staging depth, so the `double_buffer` argument is ignored.
            let cand = crate::autotune::best_candidate(spec, arch);
            return crate::autotune::space::tiling_of(&cand, spec, arch);
        }
        TilingStrategy::Heuristic => {
            let mut bm: usize = if spec.qk_dim() <= 64 { 128 } else { 64 };
            let mut bn: usize = 64;
            // Shrink until the tile fits shared memory.
            while smem_bytes(spec, bm, bn, double_buffer) > arch.smem_per_block && bn > 16 {
                bn /= 2;
            }
            while smem_bytes(spec, bm, bn, double_buffer) > arch.smem_per_block && bm > 16 {
                bm /= 2;
            }
            bm = bm.min(spec.seq_len.next_power_of_two());
            bn = bn.min(spec.kv_len.next_power_of_two());
            (bm, bn)
        }
        TilingStrategy::CostSearch => {
            let mut best = (128usize, 64usize, f64::NEG_INFINITY);
            for bm in [32usize, 64, 128, 256] {
                for bn in [32usize, 64, 128] {
                    let s = score(arch, spec, bm, bn, double_buffer);
                    if s > best.2 {
                        best = (bm, bn, s);
                    }
                }
            }
            (best.0, best.1)
        }
    };
    let bn = page_align_bn(spec, bn);
    let smem = smem_bytes(spec, bm, bn, double_buffer);
    let regs = reg_bytes(spec, bm, bn);
    Tiling {
        bm,
        bn,
        double_buffer,
        smem_bytes: smem,
        reg_bytes: regs,
        blocks_per_sm: occupancy(arch, smem, regs),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::spec::AttnVariant;

    fn spec64() -> OpSpec {
        OpSpec::benchmark(AttnVariant::Mha, 4096, 64, true)
    }

    fn spec128() -> OpSpec {
        OpSpec::benchmark(AttnVariant::Mha, 4096, 128, true)
    }

    #[test]
    fn heuristic_fits_smem_everywhere() {
        for arch in GpuArch::all() {
            for spec in [spec64(), spec128(), OpSpec::mla(4096, true)] {
                for db in [false, true] {
                    let t = choose(TilingStrategy::Heuristic, &spec, &arch, db);
                    assert!(
                        t.smem_bytes <= arch.smem_per_block,
                        "{} {:?} overflows: {} > {}",
                        arch.name,
                        (t.bm, t.bn, db),
                        t.smem_bytes,
                        arch.smem_per_block
                    );
                }
            }
        }
    }

    #[test]
    fn search_fits_smem_everywhere() {
        for arch in GpuArch::all() {
            for spec in [spec64(), spec128(), OpSpec::mla(4096, true)] {
                let t = choose(TilingStrategy::CostSearch, &spec, &arch, true);
                assert!(t.smem_bytes <= arch.smem_per_block);
            }
        }
    }

    #[test]
    fn search_at_least_as_good_as_heuristic() {
        for arch in [GpuArch::a100(), GpuArch::rtx8000(), GpuArch::t4()] {
            for spec in [spec64(), spec128()] {
                let h = choose(TilingStrategy::Heuristic, &spec, &arch, true);
                let s = choose(TilingStrategy::CostSearch, &spec, &arch, true);
                assert!(
                    score(&arch, &spec, s.bm, s.bn, true)
                        >= score(&arch, &spec, h.bm, h.bn, true),
                    "search worse than heuristic on {}",
                    arch.name
                );
            }
        }
    }

    #[test]
    fn turing_head128_shrinks_tiles() {
        // 64 KB shared memory cannot hold BM=128 tiles at head-dim 128
        // with double buffering; the heuristic must shrink.
        let t = choose(TilingStrategy::Heuristic, &spec128(), &GpuArch::t4(), true);
        assert!(t.smem_bytes <= GpuArch::t4().smem_per_block);
        assert!(t.bm <= 64 || t.bn <= 32);
    }

    #[test]
    fn tiles_never_exceed_sequence() {
        let spec = OpSpec::benchmark(AttnVariant::Mha, 512, 64, true);
        let t = choose(TilingStrategy::CostSearch, &spec, &GpuArch::a100(), true);
        assert!(t.bm <= 512 && t.bn <= 512);
    }

    #[test]
    fn occupancy_positive() {
        let t = choose(TilingStrategy::Heuristic, &spec64(), &GpuArch::a100(), false);
        assert!(t.blocks_per_sm >= 1);
    }
}
