//! Stage 1b — **parameter analysis & reasoning** (§3.2.2).
//!
//! Takes the TL Sketch and produces the complete TL Code by supplementing
//! every statement with the details translation needs, exactly the steps
//! the paper's Listing-4 prompt drives the LLM through:
//!
//! 1. choose the tile sizes `BM`/`BN` from the target GPU's shared-memory
//!    and occupancy constraints ([`tiling`]);
//! 2. insert `Allocate` statements for every tensor at every memory level
//!    it touches (global tensors with their block offsets; shared tiles;
//!    register accumulators);
//! 3. attach block coordinates to each `Copy` (`in coordinate [L = i]`);
//! 4. expand the `Softmax` running-stat list to include the accumulator
//!    that must be rescaled, and rewrite the loop bound to skip fully
//!    masked KV blocks under a causal mask;
//! 5. insert the fragment-layout `Reshape` between the fused GEMMs
//!    (`mma_C → mma_A`) — the step whose omission is Appendix-B failure 1;
//! 6. optionally add the guarded next-tile prefetch (double buffering).
//!
//! The [`profiles::LlmProfile`] selects which of these rules fire and can
//! inject the Appendix-B defects for the single-stage ablation.
//!
//! Backward sketches (program names carrying `_bwd_dq|_bwd_dk|_bwd_dv`)
//! route to the [`backward`] twin of this module, which applies the same
//! six steps re-oriented per gradient (block side vs stream side, causal
//! start/end clipping, the mma_C→mma_A relayout before the accumulate
//! GEMM).

pub mod backward;
pub mod profiles;
pub mod tiling;

use std::collections::BTreeMap;

use crate::perfmodel::gpu::GpuArch;
use crate::sketch::spec::{AttnVariant, KvLayout, OpSpec, ScorePattern};
use crate::tl::ast::{ComputeOp, Stmt, TlProgram};
use crate::tl::expr::Expr;
use crate::tl::types::{DType, MemSpace};
use profiles::{FailureMode, LlmProfile};
use tiling::Tiling;

/// Tensor roles inferred from the sketch's dataflow. The score GEMM is
/// recognized by its formal transpose (`Q @ K.T`); the PV GEMM by
/// accumulation into the output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Role {
    QLike,
    KLike,
    VLike,
    Score,
    Acc,
    Stat,
}

/// Result of stage 1b: the full TL Code plus the tiling facts.
#[derive(Debug, Clone)]
pub struct Reasoned {
    pub program: TlProgram,
    pub tiling: Tiling,
}

/// Run parameter analysis & reasoning over a sketch.
pub fn reason(
    sketch: &TlProgram,
    spec: &OpSpec,
    arch: &GpuArch,
    profile: &LlmProfile,
) -> Reasoned {
    let tiling = tiling::choose(profile.tiling, spec, arch, profile.prefetch);
    reason_with_tiling(sketch, spec, profile, tiling)
}

/// Stage 1b with an externally chosen tiling — the entry point the
/// autotuner uses ([`crate::pipeline::run_tuned`]) to inject a searched
/// schedule instead of the profile's strategy. Prefetch is emitted only
/// when both the profile asks for it and the tiling actually budgets the
/// double buffer (a single-staged autotune candidate disables it).
pub fn reason_with_tiling(
    sketch: &TlProgram,
    spec: &OpSpec,
    profile: &LlmProfile,
    tiling: Tiling,
) -> Reasoned {
    if backward::grad_of(&sketch.name).is_some() {
        return backward::reason_backward(sketch, spec, profile, tiling);
    }
    let roles = infer_roles(sketch);
    let prefetch = profile.prefetch && tiling.double_buffer;
    let ctx = Ctx { spec, profile, prefetch, roles: &roles };

    let mut stmts: Vec<Stmt> = Vec::new();
    // 1. Concrete parameters.
    stmts.push(param("BM", tiling.bm as i64));
    stmts.push(param("BN", tiling.bn as i64));
    stmts.push(param("HeadDim", spec.qk_dim() as i64));
    stmts.push(param("VDim", spec.v_head_dim as i64));
    stmts.push(param("seq_len", spec.seq_len as i64));
    stmts.push(param("kv_len", spec.kv_len as i64));
    if spec.group_size() > 1 {
        stmts.push(param("group_size", spec.group_size() as i64));
    }
    if spec.variant == AttnVariant::Nsa {
        stmts.push(param("num_selected", spec.nsa_topk as i64));
        stmts.push(param("window", spec.nsa_window as i64));
    }
    // Layout parameters: the engines and backends key gather granularity
    // and window clipping off these bindings.
    match spec.kv_layout {
        KvLayout::Contiguous => {}
        KvLayout::Paged { page_size } => {
            // The gather assembles whole pages into a BN-row tile, so the
            // effective page is the largest divisor of BN not exceeding
            // the requested size (a no-op for the usual power-of-two
            // page/tile pairs). This binding is authoritative: engines,
            // backends and table builders all read it from the program.
            let page = (1..=page_size.min(tiling.bn))
                .rev()
                .find(|p| tiling.bn % p == 0)
                .unwrap_or(1);
            stmts.push(param("page_size", page as i64));
        }
        KvLayout::Sliding { window } => stmts.push(param("window", window as i64)),
    }
    // Score-pattern parameters. Block-sparse converts the element-level
    // (block, topk) budget into a count of BN-row tiles: the streaming
    // loop visits exactly `sel_topk` entries of the selection table, so
    // with topk covering every block the loop degenerates to the dense
    // sweep (the ⊇-containment law `tests/patterns.rs` pins bitwise).
    match spec.pattern {
        ScorePattern::Dense => {}
        ScorePattern::BlockSparse { block, topk } => {
            let total_tiles = spec.kv_len.div_ceil(tiling.bn).max(1);
            let sel_tiles = (topk * block).div_ceil(tiling.bn).clamp(1, total_tiles);
            stmts.push(param("sel_topk", sel_tiles as i64));
        }
        ScorePattern::WindowGlobal { window, n_global } => {
            stmts.push(param("window", window as i64));
            stmts.push(param("n_global", n_global as i64));
        }
    }

    // 2. Allocations, in hierarchy order.
    stmts.extend(ctx.global_allocs(sketch));
    stmts.extend(ctx.shared_allocs(sketch));
    stmts.extend(ctx.register_allocs(sketch));

    // 3-6. Statement-level rewriting.
    for s in &sketch.stmts {
        stmts.extend(ctx.rewrite(s, None));
    }

    let name = sketch.name.strip_suffix("_sketch").unwrap_or(&sketch.name).to_string();
    Reasoned { program: TlProgram::new(name, stmts), tiling }
}

fn param(name: &str, value: i64) -> Stmt {
    Stmt::Param { name: name.into(), value }
}

pub(crate) fn infer_roles(sketch: &TlProgram) -> BTreeMap<String, Role> {
    let mut roles = BTreeMap::new();
    sketch.walk(|s| {
        if let Stmt::Compute { op, inputs, with, output, accumulate, .. } = s {
            match op {
                ComputeOp::Gemm => {
                    if inputs.len() == 2 && inputs[1].transposed {
                        // Score GEMM: Q @ K.T
                        roles.entry(inputs[0].name.clone()).or_insert(Role::QLike);
                        roles.insert(inputs[1].name.clone(), Role::KLike);
                        if let Some(o) = output {
                            roles.insert(o.clone(), Role::Score);
                        }
                    } else if inputs.len() == 2 {
                        // PV GEMM: P @ V (accumulating)
                        roles.insert(inputs[1].name.clone(), Role::VLike);
                        if let Some(o) = output {
                            if *accumulate {
                                roles.insert(o.clone(), Role::Acc);
                            }
                        }
                    }
                }
                ComputeOp::Softmax => {
                    for w in with {
                        roles.insert(w.clone(), Role::Stat);
                    }
                }
                _ => {}
            }
        }
    });
    roles
}

struct Ctx<'a> {
    spec: &'a OpSpec,
    profile: &'a LlmProfile,
    /// Emit the guarded double-buffer prefetch (profile knob gated by the
    /// tiling's staging budget).
    prefetch: bool,
    roles: &'a BTreeMap<String, Role>,
}

impl<'a> Ctx<'a> {
    /// Block-tile shape of a tensor by role.
    fn tile_shape(&self, name: &str) -> Vec<Expr> {
        match self.roles.get(name) {
            Some(Role::QLike) => vec![Expr::sym("BM"), Expr::sym("HeadDim")],
            Some(Role::KLike) => vec![Expr::sym("BN"), Expr::sym("HeadDim")],
            Some(Role::VLike) => vec![Expr::sym("BN"), Expr::sym("VDim")],
            Some(Role::Score) => vec![Expr::sym("BM"), Expr::sym("BN")],
            Some(Role::Acc) => vec![Expr::sym("BM"), Expr::sym("VDim")],
            Some(Role::Stat) => vec![Expr::sym("BM"), Expr::int(1)],
            None => vec![Expr::sym("BM"), Expr::sym("HeadDim")],
        }
    }

    /// Full global shape of a tensor by role.
    fn global_shape(&self, name: &str) -> (Vec<Expr>, &'static str) {
        match self.roles.get(name) {
            Some(Role::KLike) => {
                (vec![Expr::sym("kv_len"), Expr::sym("HeadDim")], "kv_offset")
            }
            Some(Role::VLike) => (vec![Expr::sym("kv_len"), Expr::sym("VDim")], "kv_offset"),
            Some(Role::Acc) => (vec![Expr::sym("seq_len"), Expr::sym("VDim")], "q_offset"),
            _ => (vec![Expr::sym("seq_len"), Expr::sym("HeadDim")], "q_offset"),
        }
    }

    fn global_allocs(&self, sketch: &TlProgram) -> Vec<Stmt> {
        let mut seen = Vec::new();
        let mut out = Vec::new();
        sketch.walk(|s| {
            if let Stmt::Copy { tensor, src, dst, .. } = s {
                let touches_global = *src == MemSpace::Global || *dst == MemSpace::Global;
                if touches_global && !seen.contains(tensor) {
                    seen.push(tensor.clone());
                    let (shape, offset) = self.global_shape(tensor);
                    out.push(Stmt::Allocate {
                        name: tensor.clone(),
                        space: MemSpace::Global,
                        shape,
                        offset: Some(Expr::sym(offset)),
                        dtype: Some(self.spec.dtype),
                    });
                }
            }
        });
        out
    }

    fn shared_allocs(&self, sketch: &TlProgram) -> Vec<Stmt> {
        let mut seen = Vec::new();
        let mut out = Vec::new();
        sketch.walk(|s| {
            if let Stmt::Copy { tensor, dst: MemSpace::Shared, .. } = s {
                if !seen.contains(tensor) {
                    seen.push(tensor.clone());
                    out.push(Stmt::Allocate {
                        name: tensor.clone(),
                        space: MemSpace::Shared,
                        shape: self.tile_shape(tensor),
                        offset: None,
                        dtype: Some(self.spec.dtype),
                    });
                }
            }
        });
        out
    }

    fn register_allocs(&self, sketch: &TlProgram) -> Vec<Stmt> {
        let mut seen: Vec<String> = Vec::new();
        let mut out = Vec::new();
        let mut push = |name: &str, shape: Vec<Expr>, dtype: DType, out: &mut Vec<Stmt>| {
            if !seen.contains(&name.to_string()) {
                seen.push(name.to_string());
                out.push(Stmt::Allocate {
                    name: name.into(),
                    space: MemSpace::Register,
                    shape,
                    offset: None,
                    dtype: Some(dtype),
                });
            }
        };
        // Tensors explicitly copied into registers.
        sketch.walk(|s| {
            if let Stmt::Copy { tensor, dst: MemSpace::Register, .. } = s {
                push(tensor, self.tile_shape(tensor), self.spec.dtype, &mut out);
            }
        });
        // GEMM outputs and softmax stats live in fp32 registers.
        for (name, role) in self.roles {
            match role {
                Role::Score | Role::Acc | Role::Stat => {
                    push(name, self.tile_shape(name), DType::F32, &mut out)
                }
                _ => {}
            }
        }
        out
    }

    /// Causal loop bound: a q-block `block_idx` only attends KV blocks
    /// `[0, ceil((block_idx+1)*BM / BN))` — the block-skipping
    /// optimization the paper credits for the long-context causal wins.
    /// Ceiling division keeps the partially-masked diagonal block when
    /// `BN > BM`.
    fn causal_bound(&self) -> Expr {
        Expr::div(
            Expr::sub(
                Expr::add(
                    Expr::mul(Expr::add(Expr::sym("block_idx"), Expr::int(1)), Expr::sym("BM")),
                    Expr::sym("BN"),
                ),
                Expr::int(1),
            ),
            Expr::sym("BN"),
        )
    }

    fn rewrite(&self, s: &Stmt, loop_var: Option<&str>) -> Vec<Stmt> {
        match s {
            Stmt::Copy { tensor, shape, coord, src, dst } => {
                let mut shape = shape.clone();
                let mut coord = coord.clone();
                if *src == MemSpace::Global || *dst == MemSpace::Global {
                    if shape.is_none() {
                        shape = Some(self.tile_shape(tensor));
                    }
                    if coord.is_empty() {
                        let l = match (self.roles.get(tensor.as_str()), loop_var) {
                            // K/V tiles stream with the loop variable —
                            // through the block table under a paged layout
                            // (the coordinate-gather form).
                            (Some(Role::KLike | Role::VLike), Some(v)) => {
                                if matches!(self.spec.kv_layout, KvLayout::Paged { .. }) {
                                    Expr::idx("block_table", Expr::sym(v))
                                } else {
                                    Expr::sym(v)
                                }
                            }
                            _ => Expr::sym("block_idx"),
                        };
                        coord.push(("L".into(), l));
                    }
                    // GQA/MQA: KV tensors are indexed by the shared KV head.
                    if self.spec.group_size() > 1
                        && matches!(
                            self.roles.get(tensor.as_str()),
                            Some(Role::KLike | Role::VLike)
                        )
                        && !coord.iter().any(|(n, _)| n == "H")
                    {
                        coord.insert(
                            0,
                            (
                                "H".into(),
                                Expr::div(Expr::sym("head_idx"), Expr::sym("group_size")),
                            ),
                        );
                    }
                }
                vec![Stmt::Copy { tensor: tensor.clone(), shape, coord, src: *src, dst: *dst }]
            }
            Stmt::Compute { op: ComputeOp::CausalMask, inputs, .. } => {
                let lk = loop_var.unwrap_or("i");
                let mask = |op: ComputeOp| Stmt::Compute {
                    op,
                    inputs: inputs.clone(),
                    coord: vec![
                        ("Lq".into(), Expr::sym("block_idx")),
                        ("Lk".into(), Expr::sym(lk)),
                    ],
                    with: vec![],
                    output: None,
                    accumulate: false,
                    new_var: false,
                };
                let mut out = vec![mask(ComputeOp::CausalMask)];
                // Sliding layout: also blind scores trailing the query by
                // `window` or more (same Lq/Lk coordinates). WindowGlobal
                // reuses the same mask op; its `n_global` binding exempts
                // the leading global keys (engines read both bindings).
                if matches!(self.spec.kv_layout, KvLayout::Sliding { .. })
                    || matches!(self.spec.pattern, ScorePattern::WindowGlobal { .. })
                {
                    out.push(mask(ComputeOp::WindowMask));
                }
                out
            }
            Stmt::Compute { op: ComputeOp::Gemm, inputs, output, accumulate, .. } => {
                let mut inputs = inputs.clone();
                if self.profile.failure == Some(FailureMode::GemmLayoutError) {
                    // Appendix-B Listing 2: drop the formal transpose.
                    for t in &mut inputs {
                        t.transposed = false;
                    }
                }
                let mut out = Vec::new();
                // Fused GEMM-II needs the mma_C -> mma_A fragment reshape
                // of its Score operand (Appendix-B Listing 1 omits it).
                if *accumulate
                    && self.profile.failure != Some(FailureMode::ReshapeOmission)
                {
                    if let Some(score) = inputs
                        .first()
                        .filter(|t| self.roles.get(&t.name) == Some(&Role::Score))
                    {
                        out.push(Stmt::Reshape {
                            tensor: score.name.clone(),
                            from: crate::tl::types::Layout::new(
                                crate::tl::types::Frag::C,
                                &["MMA_M", "MMA_N"],
                            ),
                            to: crate::tl::types::Layout::new(
                                crate::tl::types::Frag::A,
                                &["MMA_M", "MMA_N_new"],
                            ),
                        });
                    }
                }
                out.push(Stmt::Compute {
                    op: ComputeOp::Gemm,
                    inputs,
                    coord: vec![],
                    with: vec![],
                    output: output.clone(),
                    accumulate: *accumulate,
                    new_var: false,
                });
                out
            }
            Stmt::Compute { op: ComputeOp::Softmax, inputs, with, .. } => {
                // Extend the running-stat list with the accumulator that
                // must be rescaled by exp(m_old - m_new).
                let mut with = with.clone();
                let acc = self
                    .roles
                    .iter()
                    .find(|(_, r)| **r == Role::Acc)
                    .map(|(n, _)| n.clone())
                    .unwrap_or_else(|| "O".to_string());
                if !with.contains(&acc) {
                    with.push(acc);
                }
                vec![Stmt::Compute {
                    op: ComputeOp::Softmax,
                    inputs: inputs.clone(),
                    coord: vec![],
                    with,
                    output: None,
                    accumulate: false,
                    new_var: false,
                }]
            }
            Stmt::For { var, start, end, body } => {
                // Causal block skipping: only for the KV streaming loop.
                let mut syms = Vec::new();
                end.symbols(&mut syms);
                let is_kv_loop = syms.iter().any(|s| s == "kv_len");
                let end = if self.spec.causal && is_kv_loop {
                    self.causal_bound()
                } else {
                    end.clone()
                };
                let mut new_body: Vec<Stmt> = Vec::new();
                for b in body {
                    let rewritten = self.rewrite(b, Some(var));
                    // Guarded prefetch after the *last use* of each
                    // streamed tile: K right after the score GEMM
                    // (Listing 1 in the paper places it there — the mma
                    // hides the next tile's load latency), V after the
                    // accumulate GEMM that consumes it.
                    let was_score_gemm = matches!(
                        b,
                        Stmt::Compute { op: ComputeOp::Gemm, accumulate: false, .. }
                    );
                    let was_acc_gemm = matches!(
                        b,
                        Stmt::Compute { op: ComputeOp::Gemm, accumulate: true, .. }
                    );
                    new_body.extend(rewritten);
                    if self.prefetch && is_kv_loop && (was_score_gemm || was_acc_gemm)
                    {
                        let role = if was_score_gemm { Role::KLike } else { Role::VLike };
                        if let Some(p) = self.prefetch_stmt(var, &end, body, role) {
                            new_body.push(p);
                        }
                    }
                }
                // Sliding window: whole KV tiles strictly below the
                // block's window are skipped. Tile `i` matters only if
                // its last key row can still fall inside some query's
                // window: `(i + 1) * BN + window > block_idx * BM`
                // (conservative by one tile; WindowMask zeroes leftovers).
                if is_kv_loop
                    && matches!(self.spec.kv_layout, KvLayout::Sliding { .. })
                {
                    new_body = vec![Stmt::If {
                        lhs: Expr::add(
                            Expr::mul(
                                Expr::add(Expr::sym(var.clone()), Expr::int(1)),
                                Expr::sym("BN"),
                            ),
                            Expr::sym("window"),
                        ),
                        op: crate::tl::ast::CmpOp::Gt,
                        rhs: Expr::mul(Expr::sym("block_idx"), Expr::sym("BM")),
                        body: new_body,
                    }];
                }
                vec![Stmt::For { var: var.clone(), start: start.clone(), end, body: new_body }]
            }
            Stmt::If { lhs, op, rhs, body } => {
                let mut new_body = Vec::new();
                for b in body {
                    new_body.extend(self.rewrite(b, loop_var));
                }
                vec![Stmt::If { lhs: lhs.clone(), op: *op, rhs: rhs.clone(), body: new_body }]
            }
            other => vec![other.clone()],
        }
    }

    /// `if i < end-1: Copy tile i+1` — the double-buffer prefetch for the
    /// streamed tensors of the given role.
    fn prefetch_stmt(&self, var: &str, end: &Expr, body: &[Stmt], role: Role) -> Option<Stmt> {
        let mut copies = Vec::new();
        for b in body {
            if let Stmt::Copy { tensor, src: MemSpace::Global, dst: MemSpace::Shared, coord, .. } =
                b
            {
                // Only prefetch straight streamed tiles (not NSA's
                // indirect selected blocks, whose next index is unknown).
                if coord.is_empty() && self.roles.get(tensor.as_str()) == Some(&role) {
                    let next = Expr::add(Expr::sym(var), Expr::int(1));
                    let l = if matches!(self.spec.kv_layout, KvLayout::Paged { .. }) {
                        Expr::idx("block_table", next)
                    } else {
                        next
                    };
                    let mut coord = vec![("L".to_string(), l)];
                    if self.spec.group_size() > 1 {
                        coord.insert(
                            0,
                            (
                                "H".into(),
                                Expr::div(Expr::sym("head_idx"), Expr::sym("group_size")),
                            ),
                        );
                    }
                    copies.push(Stmt::Copy {
                        tensor: tensor.clone(),
                        shape: Some(self.tile_shape(tensor)),
                        coord,
                        src: MemSpace::Global,
                        dst: MemSpace::Shared,
                    });
                }
            }
        }
        if copies.is_empty() {
            return None;
        }
        Some(Stmt::If {
            lhs: Expr::sym(var.to_string()),
            op: crate::tl::ast::CmpOp::Lt,
            rhs: Expr::sub(end.clone(), Expr::int(1)),
            body: copies,
        })
    }
}

/// Convenience: run both stage 1a and 1b.
pub fn generate_tl_code(spec: &OpSpec, arch: &GpuArch, profile: &LlmProfile) -> Reasoned {
    let sketch = crate::sketch::generate_sketch(spec);
    reason(&sketch, spec, arch, profile)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::generate_sketch;
    use crate::tl::parser::parse_program;
    use crate::tl::printer::print_program;
    use crate::tl::types::Frag;

    fn mha() -> OpSpec {
        OpSpec::benchmark(AttnVariant::Mha, 1024, 64, true)
    }

    fn reasoned(spec: &OpSpec, profile: &LlmProfile) -> Reasoned {
        let sketch = generate_sketch(spec);
        reason(&sketch, spec, &GpuArch::a100(), profile)
    }

    #[test]
    fn reasoned_code_is_reasoned() {
        let r = reasoned(&mha(), &LlmProfile::deepseek_v3());
        assert!(r.program.is_reasoned());
        assert!(r.program.params().contains_key("BM"));
        assert!(r.program.params().contains_key("BN"));
    }

    #[test]
    fn reasoned_roundtrips_through_text() {
        for variant in [AttnVariant::Mha, AttnVariant::Gqa, AttnVariant::Mla] {
            let spec = OpSpec::benchmark(variant, 2048, 128, true);
            let r = reasoned(&spec, &LlmProfile::deepseek_r1());
            let text = print_program(&r.program);
            let back = parse_program(&text).unwrap();
            assert_eq!(r.program.stmts, back.stmts, "roundtrip for {variant}");
        }
    }

    #[test]
    fn every_global_copy_has_coordinates_and_shape() {
        let r = reasoned(&mha(), &LlmProfile::deepseek_v3());
        r.program.walk(|s| {
            if let Stmt::Copy { tensor, shape, coord, src, dst } = s {
                if *src == MemSpace::Global || *dst == MemSpace::Global {
                    assert!(shape.is_some(), "copy of {tensor} missing shape");
                    assert!(!coord.is_empty(), "copy of {tensor} missing coordinate");
                }
            }
        });
    }

    #[test]
    fn reshape_inserted_before_fused_gemm() {
        let r = reasoned(&mha(), &LlmProfile::deepseek_v3());
        // Find the loop body; the PV GEMM must be preceded by a Reshape
        // from mma_C to mma_A.
        let mut found = false;
        r.program.walk(|s| {
            if let Stmt::For { body, .. } = s {
                for w in body.windows(2) {
                    if let (
                        Stmt::Reshape { from, to, .. },
                        Stmt::Compute { op: ComputeOp::Gemm, accumulate: true, .. },
                    ) = (&w[0], &w[1])
                    {
                        assert_eq!(from.frag, Frag::C);
                        assert_eq!(to.frag, Frag::A);
                        found = true;
                    }
                }
            }
        });
        assert!(found, "no Reshape before the fused GEMM");
    }

    #[test]
    fn reshape_omission_failure_injected() {
        let p = LlmProfile::single_stage(
            LlmProfile::deepseek_v3(),
            FailureMode::ReshapeOmission,
        );
        let r = reasoned(&mha(), &p);
        let mut reshapes = 0;
        r.program.walk(|s| {
            if matches!(s, Stmt::Reshape { .. }) {
                reshapes += 1;
            }
        });
        assert_eq!(reshapes, 0);
    }

    #[test]
    fn gemm_layout_failure_drops_transpose() {
        let p = LlmProfile::single_stage(
            LlmProfile::deepseek_v3(),
            FailureMode::GemmLayoutError,
        );
        let r = reasoned(&mha(), &p);
        r.program.walk(|s| {
            if let Stmt::Compute { op: ComputeOp::Gemm, inputs, .. } = s {
                assert!(inputs.iter().all(|t| !t.transposed));
            }
        });
    }

    #[test]
    fn causal_loop_bound_skips_masked_blocks() {
        let r = reasoned(&mha(), &LlmProfile::deepseek_v3());
        let mut saw = false;
        r.program.walk(|s| {
            if let Stmt::For { end, .. } = s {
                let mut syms = Vec::new();
                end.symbols(&mut syms);
                assert!(
                    syms.contains(&"block_idx".to_string()),
                    "causal bound must depend on block_idx, got {end}"
                );
                saw = true;
            }
        });
        assert!(saw);
    }

    #[test]
    fn non_causal_keeps_full_bound() {
        let spec = OpSpec::benchmark(AttnVariant::Mha, 1024, 64, false);
        let r = reasoned(&spec, &LlmProfile::deepseek_v3());
        r.program.walk(|s| {
            if let Stmt::For { end, .. } = s {
                let mut syms = Vec::new();
                end.symbols(&mut syms);
                assert!(syms.contains(&"kv_len".to_string()));
            }
        });
    }

    #[test]
    fn gqa_kv_copies_indexed_by_group() {
        let spec = OpSpec::benchmark(AttnVariant::Gqa, 1024, 128, true);
        let r = reasoned(&spec, &LlmProfile::deepseek_v3());
        let mut kv_with_h = 0;
        r.program.walk(|s| {
            if let Stmt::Copy { tensor, coord, src: MemSpace::Global, .. } = s {
                if tensor == "K" || tensor == "V" {
                    assert!(
                        coord.iter().any(|(n, _)| n == "H"),
                        "KV copy missing group coordinate"
                    );
                    kv_with_h += 1;
                }
            }
        });
        assert!(kv_with_h >= 2);
    }

    #[test]
    fn prefetch_guard_matches_listing1() {
        let r = reasoned(&mha(), &LlmProfile::deepseek_v3());
        let mut found_guard = false;
        r.program.walk(|s| {
            if let Stmt::If { op, body, .. } = s {
                if body
                    .iter()
                    .any(|b| matches!(b, Stmt::Copy { dst: MemSpace::Shared, .. }))
                {
                    assert_eq!(*op, crate::tl::ast::CmpOp::Lt);
                    found_guard = true;
                }
            }
        });
        assert!(found_guard, "prefetch guard missing");
    }

    #[test]
    fn injected_single_stage_tiling_disables_prefetch() {
        // An autotuned candidate without a double buffer must suppress
        // the prefetch even for a prefetch-happy profile.
        let spec = mha();
        let sketch = generate_sketch(&spec);
        let mut tiling =
            super::tiling::choose(super::tiling::TilingStrategy::Heuristic, &spec, &GpuArch::a100(), false);
        tiling.double_buffer = false;
        let r = reason_with_tiling(&sketch, &spec, &LlmProfile::deepseek_v3(), tiling);
        r.program.walk(|s| {
            if let Stmt::If { body, .. } = s {
                assert!(
                    !body.iter().any(|b| matches!(b, Stmt::Copy { .. })),
                    "prefetch emitted despite single-stage tiling"
                );
            }
        });
    }

    #[test]
    fn injected_tiling_lands_in_params() {
        let spec = mha();
        let sketch = generate_sketch(&spec);
        let tiling = crate::autotune::space::tiling_of(
            &crate::autotune::space::Candidate { bm: 64, bn: 32, stages: 2, warps: 4, split_k: 1, prefetch_pages: 1 },
            &spec,
            &GpuArch::a100(),
        );
        let r = reason_with_tiling(&sketch, &spec, &LlmProfile::deepseek_v3(), tiling);
        let params = r.program.params();
        assert_eq!(params["BM"], 64);
        assert_eq!(params["BN"], 32);
    }

    #[test]
    fn claude_profile_has_no_prefetch() {
        let r = reasoned(&mha(), &LlmProfile::claude35());
        r.program.walk(|s| {
            if let Stmt::If { body, .. } = s {
                assert!(
                    !body.iter().any(|b| matches!(b, Stmt::Copy { .. })),
                    "claude35 profile must not prefetch"
                );
            }
        });
    }

    #[test]
    fn softmax_with_list_includes_accumulator() {
        let r = reasoned(&mha(), &LlmProfile::deepseek_v3());
        let mut ok = false;
        r.program.walk(|s| {
            if let Stmt::Compute { op: ComputeOp::Softmax, with, .. } = s {
                assert_eq!(with.len(), 3, "softmax must carry m, l and the accumulator");
                assert!(with.contains(&"O".to_string()));
                ok = true;
            }
        });
        assert!(ok);
    }

    #[test]
    fn allocations_cover_all_memory_levels() {
        let r = reasoned(&mha(), &LlmProfile::deepseek_v3());
        let mut spaces = std::collections::BTreeSet::new();
        r.program.walk(|s| {
            if let Stmt::Allocate { space, .. } = s {
                spaces.insert(*space);
            }
        });
        assert!(spaces.contains(&MemSpace::Global));
        assert!(spaces.contains(&MemSpace::Shared));
        assert!(spaces.contains(&MemSpace::Register));
    }

    #[test]
    fn mla_uses_asymmetric_dims() {
        let spec = OpSpec::mla(1024, true);
        let r = reasoned(&spec, &LlmProfile::deepseek_v3());
        let params = r.program.params();
        assert_eq!(params["HeadDim"], 192); // 128 nope + 64 rope
        assert_eq!(params["VDim"], 128);
    }

    #[test]
    fn nsa_keeps_indirect_coordinates() {
        let spec = OpSpec::nsa(4096);
        let r = reasoned(&spec, &LlmProfile::deepseek_v3());
        let mut sel_gathers = 0;
        r.program.walk(|s| {
            if let Stmt::Copy { coord, .. } = s {
                for (_, e) in coord {
                    if let Some((table, _)) = e.gather() {
                        assert_eq!(table, "sel_table", "NSA indirection must gather");
                        sel_gathers += 1;
                    }
                }
            }
        });
        assert!(sel_gathers >= 2, "NSA selected-block indirection lost");
        // The NSA params stay bound *and* consumed (loop bounds).
        assert!(r.program.params().contains_key("num_selected"));
        assert!(r.program.params().contains_key("window"));
    }

    #[test]
    fn block_sparse_reasons_to_a_sel_table_gather_loop() {
        let spec = OpSpec::benchmark(AttnVariant::Mha, 4096, 64, false)
            .with_pattern(ScorePattern::BlockSparse { block: 64, topk: 16 })
            .unwrap();
        let r = reasoned(&spec, &LlmProfile::deepseek_v3());
        // sel_topk = ceil(16*64 / BN) tiles, clipped to kv_len/BN.
        let params = r.program.params();
        let bn = params["BN"] as usize;
        let expect = (16usize * 64).div_ceil(bn).min(4096usize.div_ceil(bn)) as i64;
        assert_eq!(params.get("sel_topk"), Some(&expect));
        // The streaming loop runs to sel_topk and gathers via sel_table.
        let mut saw_loop = false;
        let mut gathers = 0;
        r.program.walk(|s| match s {
            Stmt::For { end, .. } => {
                let mut syms = Vec::new();
                end.symbols(&mut syms);
                if syms.contains(&"sel_topk".to_string()) {
                    saw_loop = true;
                }
            }
            Stmt::Copy { coord, .. } => {
                for (_, e) in coord {
                    if let Some((table, _)) = e.gather() {
                        assert_eq!(table, "sel_table");
                        gathers += 1;
                    }
                }
            }
            _ => {}
        });
        assert!(saw_loop, "loop bound must be sel_topk");
        assert!(gathers >= 2, "K and V must gather through sel_table");
        // No prefetch: the next selected tile's index is data-dependent.
        r.program.walk(|s| {
            if let Stmt::If { body, .. } = s {
                assert!(
                    !body.iter().any(|b| matches!(b, Stmt::Copy { .. })),
                    "no prefetch through a selection table"
                );
            }
        });
        // Roundtrips through text like every reasoned program.
        let text = print_program(&r.program);
        let back = parse_program(&text).unwrap();
        assert_eq!(r.program.stmts, back.stmts);
    }

    #[test]
    fn window_global_reasons_to_masks_with_n_global() {
        let spec = OpSpec::benchmark(AttnVariant::Mha, 4096, 64, false)
            .with_pattern(ScorePattern::WindowGlobal { window: 512, n_global: 64 })
            .unwrap();
        assert!(spec.causal, "window+global implies causal");
        let r = reasoned(&spec, &LlmProfile::deepseek_v3());
        let params = r.program.params();
        assert_eq!(params.get("window"), Some(&512));
        assert_eq!(params.get("n_global"), Some(&64));
        let mut saw_causal = false;
        let mut saw_window = false;
        r.program.walk(|s| match s {
            Stmt::Compute { op: ComputeOp::CausalMask, .. } => saw_causal = true,
            Stmt::Compute { op: ComputeOp::WindowMask, coord, .. } => {
                assert!(coord.iter().any(|(n, _)| n == "Lq"));
                saw_window = true;
            }
            _ => {}
        });
        assert!(saw_causal && saw_window, "both masks must be present");
        // Mask-only: no tile-skip guard (global keys keep every leading
        // tile live), and no gathers — the KV stream stays contiguous.
        r.program.walk(|s| {
            if let Stmt::Copy { coord, .. } = s {
                assert!(coord.iter().all(|(_, e)| e.gather().is_none()));
            }
        });
        let text = print_program(&r.program);
        let back = parse_program(&text).unwrap();
        assert_eq!(r.program.stmts, back.stmts);
    }

    #[test]
    fn paged_kv_copies_gather_through_block_table() {
        let spec = OpSpec::benchmark(AttnVariant::Mha, 1024, 64, true)
            .with_layout(KvLayout::Paged { page_size: 16 });
        let r = reasoned(&spec, &LlmProfile::deepseek_v3());
        assert_eq!(r.program.params().get("page_size"), Some(&16));
        let mut kv_gathers = 0;
        let mut q_gathers = 0;
        r.program.walk(|s| {
            if let Stmt::Copy { tensor, coord, src: MemSpace::Global, .. } = s {
                let gathered = coord.iter().any(|(_, e)| e.gather().is_some());
                if tensor == "K" || tensor == "V" {
                    assert!(gathered, "paged K/V copy must gather: {coord:?}");
                    kv_gathers += 1;
                } else {
                    assert!(!gathered, "Q/O stay dense under a paged KV cache");
                    q_gathers += 1;
                }
            }
        });
        assert!(kv_gathers >= 2, "K and V both gather");
        assert!(q_gathers >= 1);
        // Prefetch gathers the *next* tile through the table too.
        let text = crate::tl::printer::print_program(&r.program);
        assert!(text.contains("block_table[i]"), "{text}");
        assert!(text.contains("block_table[i + 1]"), "prefetch must gather: {text}");
        // And the gather form survives the text round trip.
        let back = crate::tl::parser::parse_program(&text).unwrap();
        assert_eq!(r.program.stmts, back.stmts);
    }

    #[test]
    fn sliding_emits_window_guard_and_mask() {
        let spec = OpSpec::benchmark(AttnVariant::Mha, 1024, 64, true)
            .with_layout(KvLayout::Sliding { window: 256 });
        let r = reasoned(&spec, &LlmProfile::deepseek_v3());
        assert_eq!(r.program.params().get("window"), Some(&256));
        let mut saw_mask = false;
        let mut saw_guard = false;
        r.program.walk(|s| match s {
            Stmt::Compute { op: ComputeOp::WindowMask, coord, .. } => {
                assert!(coord.iter().any(|(n, _)| n == "Lq"));
                saw_mask = true;
            }
            Stmt::If { lhs, body, .. } => {
                let mut syms = Vec::new();
                lhs.symbols(&mut syms);
                if syms.contains(&"window".to_string()) {
                    assert!(
                        body.iter().any(|b| matches!(b, Stmt::Compute { .. })),
                        "the tile-skip guard wraps the real loop body"
                    );
                    saw_guard = true;
                }
            }
            _ => {}
        });
        assert!(saw_mask, "sliding layout must emit WindowMask");
        assert!(saw_guard, "sliding layout must emit the tile-skip guard");
    }

    #[test]
    fn contiguous_reasoning_is_unchanged_by_the_layout_refactor() {
        // The layout-polymorphic rewrite must be a strict superset: a
        // contiguous spec produces no gathers, no window params, no
        // WindowMask.
        let r = reasoned(&mha(), &LlmProfile::deepseek_v3());
        assert!(!r.program.params().contains_key("page_size"));
        assert!(!r.program.params().contains_key("window"));
        r.program.walk(|s| match s {
            Stmt::Copy { coord, .. } => {
                assert!(coord.iter().all(|(_, e)| e.gather().is_none()))
            }
            Stmt::Compute { op, .. } => assert_ne!(*op, ComputeOp::WindowMask),
            _ => {}
        });
    }

    #[test]
    fn tl_code_is_a_couple_dozen_lines() {
        // "hundreds of lines of low-level CUDA code to a mere dozen lines
        // of TL code" — the reasoned form adds allocations/params but must
        // stay ~2 orders below CUDA scale.
        let r = reasoned(&mha(), &LlmProfile::deepseek_r1());
        assert!(r.program.stmt_count() < 45, "TL code too large: {}", r.program.stmt_count());
    }
}
