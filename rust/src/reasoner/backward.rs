//! Stage 1b for the **backward pass**: parameter analysis & reasoning
//! over the FlashAttention-2-style backward sketches
//! ([`crate::sketch::backward_sketches`]).
//!
//! The forward reasoner infers tensor roles from the sketch's dataflow
//! (the score GEMM is recognized by its formal transpose). The backward
//! dataflow is not role-inferable the same way — `Q @ Kᵀ` and `dO @ Vᵀ`
//! are structurally identical — so this module reasons from the backward
//! family's *fixed tensor vocabulary* (`Q, K, V, dO, Lse, Delta, S, P,
//! dP, dS, dQ/dK/dV`), exactly as the paper's Listing-4 prompt names its
//! tensors. The steps are the forward ones re-oriented per gradient:
//!
//! 1. tile sizes come from the same [`super::tiling`] chooser (the
//!    autotuner can inject a searched schedule through
//!    [`super::reason_with_tiling`] exactly as for the forward);
//! 2. `Allocate` statements at every level — the *block side* of a
//!    program owns `BM` rows (q rows for dQ, KV rows for dK/dV), the
//!    *stream side* flows through shared memory in `BN`-row tiles;
//! 3. block coordinates: block-side copies pin `[L = block_idx]`,
//!    stream-side copies ride the loop variable — through the block
//!    table (`[L = block_table[i]]`) for paged K/V, in either position;
//! 4. causal work skipping: the dQ program clips its KV loop *end* with
//!    the forward's ceiling bound; dK/dV clip their q-loop *start* at
//!    `block_idx * BM / BN` (tiles fully above the diagonal are exactly
//!    masked — DESIGN.md §10);
//! 5. the `mma_C → mma_A` fragment `Reshape` before each accumulate
//!    GEMM (`dS` for dQ/dK, `P` for dV) — the same Appendix-B failure
//!    class as the forward's fused GEMM-II;
//! 6. the guarded double-buffer prefetch for the dQ program's streamed
//!    K/V tiles (dK/dV stream four tensors per iteration, which would
//!    double a much larger staging footprint, so they stay single-
//!    buffered).
//!
//! Masking needs no transposed twin: the TL mask ops compute `qpos = Lq
//! * rows + r` and `kpos = Lk * cols + c` from the *tile's own
//! dimensions*, so the dK/dV orientation (q on rows-of-BN, KV on
//! cols-of-BM) reuses the forward mask with swapped coordinates
//! (`[Lq = i, Lk = block_idx]`).

use crate::sketch::spec::{KvLayout, OpSpec};
use crate::sketch::GradTarget;
use crate::tl::ast::{CmpOp, ComputeOp, Stmt, TlProgram};
use crate::tl::expr::Expr;
use crate::tl::types::{DType, Frag, Layout, MemSpace};

use super::profiles::{FailureMode, LlmProfile};
use super::tiling::Tiling;
use super::Reasoned;

/// The grad target encoded in a backward sketch/program name
/// (`..._bwd_dq[_sketch]`), if any. This is how [`super::reason_with_tiling`]
/// routes backward sketches here.
pub fn grad_of(name: &str) -> Option<GradTarget> {
    for g in GradTarget::all() {
        if name.contains(&format!("_bwd_{}", g.as_str())) {
            return Some(g);
        }
    }
    None
}

/// Stage 1b over a backward sketch (see module docs).
pub fn reason_backward(
    sketch: &TlProgram,
    spec: &OpSpec,
    profile: &LlmProfile,
    tiling: Tiling,
) -> Reasoned {
    let grad = grad_of(&sketch.name).expect("backward sketch name must carry the grad target");
    let prefetch = profile.prefetch && tiling.double_buffer && grad == GradTarget::DQ;
    let ctx = Ctx { spec, profile, grad, prefetch };

    let mut stmts: Vec<Stmt> = Vec::new();
    stmts.push(param("BM", tiling.bm as i64));
    stmts.push(param("BN", tiling.bn as i64));
    stmts.push(param("HeadDim", spec.qk_dim() as i64));
    stmts.push(param("VDim", spec.v_head_dim as i64));
    stmts.push(param("seq_len", spec.seq_len as i64));
    stmts.push(param("kv_len", spec.kv_len as i64));
    if spec.group_size() > 1 {
        stmts.push(param("group_size", spec.group_size() as i64));
    }
    match spec.kv_layout {
        KvLayout::Contiguous => {}
        KvLayout::Paged { page_size } => {
            // The backward gathers K/V at both tile heights: `BN`-row
            // stream tiles (dQ) and `BM`-row block tiles (dK/dV), so the
            // effective page must divide both — the largest divisor of
            // gcd(BM, BN) not exceeding the requested size (a no-op for
            // the usual power-of-two page/tile pairs).
            let g = gcd(tiling.bm, tiling.bn);
            let page = (1..=page_size.min(g)).rev().find(|p| g % p == 0).unwrap_or(1);
            stmts.push(param("page_size", page as i64));
        }
        KvLayout::Sliding { window } => stmts.push(param("window", window as i64)),
    }

    stmts.extend(ctx.global_allocs(sketch));
    stmts.extend(ctx.shared_allocs(sketch));
    stmts.extend(ctx.register_allocs(sketch));

    for s in &sketch.stmts {
        stmts.extend(ctx.rewrite(s, None));
    }

    let name = sketch.name.strip_suffix("_sketch").unwrap_or(&sketch.name).to_string();
    Reasoned { program: TlProgram::new(name, stmts), tiling }
}

fn param(name: &str, value: i64) -> Stmt {
    Stmt::Param { name: name.into(), value }
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

struct Ctx<'a> {
    spec: &'a OpSpec,
    profile: &'a LlmProfile,
    grad: GradTarget,
    prefetch: bool,
}

impl<'a> Ctx<'a> {
    /// Does this tensor belong to the block the program owns (BM rows),
    /// as opposed to the streamed side (BN-row tiles)?
    fn is_block_side(&self, name: &str) -> bool {
        match self.grad {
            GradTarget::DQ => matches!(name, "Q" | "dO" | "Lse" | "Delta" | "dQ"),
            GradTarget::DK => matches!(name, "K" | "V" | "dK"),
            GradTarget::DV => matches!(name, "K" | "dV"),
        }
    }

    /// Column dimension of a named tensor tile.
    fn cols(&self, name: &str) -> Expr {
        match name {
            "Q" | "K" | "dQ" | "dK" => Expr::sym("HeadDim"),
            "V" | "dO" | "dV" => Expr::sym("VDim"),
            "Lse" | "Delta" => Expr::int(1),
            // Score-shaped tiles: columns span the *other* side's rows.
            _ => {
                if self.grad == GradTarget::DQ {
                    Expr::sym("BN")
                } else {
                    Expr::sym("BM")
                }
            }
        }
    }

    /// Block-tile shape of a named tensor.
    fn tile_shape(&self, name: &str) -> Vec<Expr> {
        match name {
            "S" | "P" | "dP" | "dS" => {
                // Score orientation: q rows x KV cols for dQ, streamed q
                // rows x block KV cols for dK/dV.
                if self.grad == GradTarget::DQ {
                    vec![Expr::sym("BM"), Expr::sym("BN")]
                } else {
                    vec![Expr::sym("BN"), Expr::sym("BM")]
                }
            }
            _ => {
                let rows =
                    if self.is_block_side(name) { Expr::sym("BM") } else { Expr::sym("BN") };
                vec![rows, self.cols(name)]
            }
        }
    }

    /// Full global shape + offset symbol of a named tensor.
    fn global_shape(&self, name: &str) -> (Vec<Expr>, &'static str) {
        match name {
            "K" | "V" | "dK" | "dV" => (vec![Expr::sym("kv_len"), self.cols(name)], "kv_offset"),
            _ => (vec![Expr::sym("seq_len"), self.cols(name)], "q_offset"),
        }
    }

    /// Element type of a named tensor: streamed operands keep the spec
    /// dtype; per-row softmax stats and every gradient/score tile carry
    /// f32 (the backward is numerically f32 end to end past the loads).
    fn dtype_of(&self, name: &str) -> DType {
        match name {
            "Q" | "K" | "V" | "dO" => self.spec.dtype,
            _ => DType::F32,
        }
    }

    fn global_allocs(&self, sketch: &TlProgram) -> Vec<Stmt> {
        let mut seen = Vec::new();
        let mut out = Vec::new();
        sketch.walk(|s| {
            if let Stmt::Copy { tensor, src, dst, .. } = s {
                let touches_global = *src == MemSpace::Global || *dst == MemSpace::Global;
                if touches_global && !seen.contains(tensor) {
                    seen.push(tensor.clone());
                    let (shape, offset) = self.global_shape(tensor);
                    out.push(Stmt::Allocate {
                        name: tensor.clone(),
                        space: MemSpace::Global,
                        shape,
                        offset: Some(Expr::sym(offset)),
                        dtype: Some(self.dtype_of(tensor)),
                    });
                }
            }
        });
        out
    }

    fn shared_allocs(&self, sketch: &TlProgram) -> Vec<Stmt> {
        let mut seen = Vec::new();
        let mut out = Vec::new();
        sketch.walk(|s| {
            if let Stmt::Copy { tensor, dst: MemSpace::Shared, .. } = s {
                if !seen.contains(tensor) {
                    seen.push(tensor.clone());
                    out.push(Stmt::Allocate {
                        name: tensor.clone(),
                        space: MemSpace::Shared,
                        shape: self.tile_shape(tensor),
                        offset: None,
                        dtype: Some(self.dtype_of(tensor)),
                    });
                }
            }
        });
        out
    }

    fn register_allocs(&self, sketch: &TlProgram) -> Vec<Stmt> {
        let mut seen: Vec<String> = Vec::new();
        let mut out = Vec::new();
        let mut push = |name: &str, shape: Vec<Expr>, dtype: DType, out: &mut Vec<Stmt>| {
            if !seen.contains(&name.to_string()) {
                seen.push(name.to_string());
                out.push(Stmt::Allocate {
                    name: name.into(),
                    space: MemSpace::Register,
                    shape,
                    offset: None,
                    dtype: Some(dtype),
                });
            }
        };
        // Tensors explicitly copied into registers (block-side operands
        // and the streamed per-row stats).
        sketch.walk(|s| {
            if let Stmt::Copy { tensor, dst: MemSpace::Register, .. } = s {
                push(tensor, self.tile_shape(tensor), self.dtype_of(tensor), &mut out);
            }
        });
        // Score-shaped compute tiles and the gradient accumulator live in
        // fp32 registers; allocate exactly the ones this program computes.
        sketch.walk(|s| {
            if let Stmt::Compute { output: Some(o), .. } = s {
                if matches!(o.as_str(), "S" | "P" | "dP" | "dS" | "dQ" | "dK" | "dV") {
                    push(o, self.tile_shape(o), DType::F32, &mut out);
                }
            }
        });
        out
    }

    /// Block coordinate expression for a global copy of `tensor` at the
    /// streamed index `idx` (or the block's own row for block-side
    /// tensors). Paged K/V go through the block table in either position.
    fn l_coord(&self, tensor: &str, loop_var: Option<&str>) -> Expr {
        let base = if self.is_block_side(tensor) {
            Expr::sym("block_idx")
        } else {
            Expr::sym(loop_var.unwrap_or("i"))
        };
        if matches!(tensor, "K" | "V")
            && matches!(self.spec.kv_layout, KvLayout::Paged { .. })
        {
            Expr::idx("block_table", base)
        } else {
            base
        }
    }

    /// Mask coordinates in this program's score orientation.
    fn mask_coords(&self, loop_var: Option<&str>) -> Vec<(String, Expr)> {
        let lv = Expr::sym(loop_var.unwrap_or("i"));
        match self.grad {
            GradTarget::DQ => {
                vec![("Lq".into(), Expr::sym("block_idx")), ("Lk".into(), lv)]
            }
            _ => vec![("Lq".into(), lv), ("Lk".into(), Expr::sym("block_idx"))],
        }
    }

    /// Causal q-loop start for the dK/dV programs: q tiles strictly above
    /// the diagonal (`(i+1) * BN <= block_idx * BM`) are fully masked, so
    /// the sweep starts at `block_idx * BM / BN` (floor — the boundary
    /// tile stays, the mask zeroes its upper corner).
    fn causal_start(&self) -> Expr {
        Expr::div(Expr::mul(Expr::sym("block_idx"), Expr::sym("BM")), Expr::sym("BN"))
    }

    /// Causal KV-loop end for the dQ program (the forward's ceiling
    /// bound: `ceil((block_idx + 1) * BM / BN)`).
    fn causal_end(&self) -> Expr {
        Expr::div(
            Expr::sub(
                Expr::add(
                    Expr::mul(Expr::add(Expr::sym("block_idx"), Expr::int(1)), Expr::sym("BM")),
                    Expr::sym("BN"),
                ),
                Expr::int(1),
            ),
            Expr::sym("BN"),
        )
    }

    fn rewrite(&self, s: &Stmt, loop_var: Option<&str>) -> Vec<Stmt> {
        match s {
            Stmt::Copy { tensor, shape, coord, src, dst } => {
                let mut shape = shape.clone();
                let mut coord = coord.clone();
                if *src == MemSpace::Global || *dst == MemSpace::Global {
                    if shape.is_none() {
                        shape = Some(self.tile_shape(tensor));
                    }
                    if coord.is_empty() {
                        coord.push(("L".into(), self.l_coord(tensor, loop_var)));
                    }
                    // GQA/MQA: K/V loads are indexed by the shared KV head.
                    if self.spec.group_size() > 1
                        && matches!(tensor.as_str(), "K" | "V")
                        && *src == MemSpace::Global
                        && !coord.iter().any(|(n, _)| n == "H")
                    {
                        coord.insert(
                            0,
                            (
                                "H".into(),
                                Expr::div(Expr::sym("head_idx"), Expr::sym("group_size")),
                            ),
                        );
                    }
                }
                vec![Stmt::Copy { tensor: tensor.clone(), shape, coord, src: *src, dst: *dst }]
            }
            Stmt::Compute { op: ComputeOp::CausalMask, inputs, .. } => {
                let mask = |op: ComputeOp| Stmt::Compute {
                    op,
                    inputs: inputs.clone(),
                    coord: self.mask_coords(loop_var),
                    with: vec![],
                    output: None,
                    accumulate: false,
                    new_var: false,
                };
                let mut out = vec![mask(ComputeOp::CausalMask)];
                if matches!(self.spec.kv_layout, KvLayout::Sliding { .. }) {
                    out.push(mask(ComputeOp::WindowMask));
                }
                out
            }
            Stmt::Compute { op: ComputeOp::Gemm, inputs, output, accumulate, .. } => {
                let mut inputs = inputs.clone();
                if self.profile.failure == Some(FailureMode::GemmLayoutError) {
                    for t in &mut inputs {
                        t.transposed = false;
                    }
                }
                let mut out = Vec::new();
                // The accumulate GEMM consumes a tile produced in the
                // mma_C fragment (dS via the dP GEMM's layout, P via the
                // recomputed S): the mma_C -> mma_A relayout is as
                // mandatory as for the forward's fused GEMM-II.
                if *accumulate && self.profile.failure != Some(FailureMode::ReshapeOmission) {
                    if let Some(a) = inputs.first() {
                        if matches!(a.name.as_str(), "S" | "P" | "dP" | "dS") {
                            out.push(Stmt::Reshape {
                                tensor: a.name.clone(),
                                from: Layout::new(Frag::C, &["MMA_M", "MMA_N"]),
                                to: Layout::new(Frag::A, &["MMA_M", "MMA_N_new"]),
                            });
                        }
                    }
                }
                out.push(Stmt::Compute {
                    op: ComputeOp::Gemm,
                    inputs,
                    coord: vec![],
                    with: vec![],
                    output: output.clone(),
                    accumulate: *accumulate,
                    new_var: false,
                });
                out
            }
            Stmt::For { var, start, end, body } => {
                let (start, end) = if self.spec.causal {
                    match self.grad {
                        GradTarget::DQ => (start.clone(), self.causal_end()),
                        _ => (self.causal_start(), end.clone()),
                    }
                } else {
                    (start.clone(), end.clone())
                };
                let mut new_body: Vec<Stmt> = Vec::new();
                for b in body {
                    let was_acc_gemm = matches!(
                        b,
                        Stmt::Compute { op: ComputeOp::Gemm, accumulate: true, .. }
                    );
                    new_body.extend(self.rewrite(b, Some(var)));
                    if self.prefetch && was_acc_gemm {
                        if let Some(p) = self.prefetch_stmt(var, &end) {
                            new_body.push(p);
                        }
                    }
                }
                // Sliding window: skip tiles that cannot intersect any
                // query's trailing window (WindowMask zeroes leftovers).
                if matches!(self.spec.kv_layout, KvLayout::Sliding { .. }) {
                    let guard = match self.grad {
                        // KV tile i is alive while its last key row can
                        // still fall inside some query's window.
                        GradTarget::DQ => Stmt::If {
                            lhs: Expr::add(
                                Expr::mul(
                                    Expr::add(Expr::sym(var.clone()), Expr::int(1)),
                                    Expr::sym("BN"),
                                ),
                                Expr::sym("window"),
                            ),
                            op: CmpOp::Gt,
                            rhs: Expr::mul(Expr::sym("block_idx"), Expr::sym("BM")),
                            body: new_body,
                        },
                        // q tile i is alive while its first query row
                        // still sees this KV block's window.
                        _ => Stmt::If {
                            lhs: Expr::mul(Expr::sym(var.clone()), Expr::sym("BN")),
                            op: CmpOp::Lt,
                            rhs: Expr::add(
                                Expr::mul(
                                    Expr::add(Expr::sym("block_idx"), Expr::int(1)),
                                    Expr::sym("BM"),
                                ),
                                Expr::sym("window"),
                            ),
                            body: new_body,
                        },
                    };
                    new_body = vec![guard];
                }
                vec![Stmt::For { var: var.clone(), start, end, body: new_body }]
            }
            Stmt::If { lhs, op, rhs, body } => {
                let mut new_body = Vec::new();
                for b in body {
                    new_body.extend(self.rewrite(b, loop_var));
                }
                vec![Stmt::If { lhs: lhs.clone(), op: *op, rhs: rhs.clone(), body: new_body }]
            }
            other => vec![other.clone()],
        }
    }

    /// `if i < end-1: Copy K/V tile i+1` — the dQ program's double-buffer
    /// prefetch of the streamed K/V tiles (placed after the accumulate
    /// GEMM, the last use of the current K tile).
    fn prefetch_stmt(&self, var: &str, end: &Expr) -> Option<Stmt> {
        let next = Expr::add(Expr::sym(var), Expr::int(1));
        let mut copies = Vec::new();
        for tensor in ["K", "V"] {
            let l = if matches!(self.spec.kv_layout, KvLayout::Paged { .. }) {
                Expr::idx("block_table", next.clone())
            } else {
                next.clone()
            };
            let mut coord = vec![("L".to_string(), l)];
            if self.spec.group_size() > 1 {
                coord.insert(
                    0,
                    ("H".into(), Expr::div(Expr::sym("head_idx"), Expr::sym("group_size"))),
                );
            }
            copies.push(Stmt::Copy {
                tensor: tensor.to_string(),
                shape: Some(self.tile_shape(tensor)),
                coord,
                src: MemSpace::Global,
                dst: MemSpace::Shared,
            });
        }
        Some(Stmt::If {
            lhs: Expr::sym(var.to_string()),
            op: CmpOp::Lt,
            rhs: Expr::sub(end.clone(), Expr::int(1)),
            body: copies,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::gpu::GpuArch;
    use crate::reasoner::reason;
    use crate::sketch::spec::{AttnVariant, Direction};
    use crate::sketch::{backward_sketches, generate_sketch};
    use crate::tl::parser::parse_program;
    use crate::tl::printer::print_program;

    fn bwd_spec(causal: bool) -> OpSpec {
        OpSpec::benchmark(AttnVariant::Mha, 1024, 64, causal)
            .with_direction(Direction::Backward)
    }

    #[test]
    fn backward_programs_reason_and_roundtrip() {
        let spec = bwd_spec(true);
        for (grad, sk) in backward_sketches(&spec) {
            let r = reason(&sk, &spec, &GpuArch::a100(), &LlmProfile::deepseek_v3());
            assert!(r.program.is_reasoned(), "{grad}");
            assert!(r.program.params().contains_key("BM"));
            let text = print_program(&r.program);
            let back = parse_program(&text).unwrap();
            assert_eq!(r.program.stmts, back.stmts, "{grad} roundtrip:\n{text}");
        }
    }

    #[test]
    fn dq_clips_loop_end_dk_dv_clip_loop_start() {
        let spec = bwd_spec(true);
        for (grad, sk) in backward_sketches(&spec) {
            let r = reason(&sk, &spec, &GpuArch::a100(), &LlmProfile::deepseek_v3());
            r.program.walk(|s| {
                if let Stmt::For { start, end, .. } = s {
                    let mut start_syms = Vec::new();
                    start.symbols(&mut start_syms);
                    let mut end_syms = Vec::new();
                    end.symbols(&mut end_syms);
                    match grad {
                        GradTarget::DQ => {
                            assert!(
                                end_syms.contains(&"block_idx".to_string()),
                                "dQ end must skip masked KV tiles: {end}"
                            );
                        }
                        _ => {
                            assert!(
                                start_syms.contains(&"block_idx".to_string()),
                                "{grad} start must skip masked q tiles: {start}"
                            );
                        }
                    }
                }
            });
        }
    }

    #[test]
    fn mask_coordinates_follow_the_score_orientation() {
        let spec = bwd_spec(true);
        for (grad, sk) in backward_sketches(&spec) {
            let r = reason(&sk, &spec, &GpuArch::a100(), &LlmProfile::deepseek_v3());
            let mut saw = false;
            r.program.walk(|s| {
                if let Stmt::Compute { op: ComputeOp::CausalMask, coord, .. } = s {
                    saw = true;
                    let lq = &coord.iter().find(|(n, _)| n == "Lq").unwrap().1;
                    let mut syms = Vec::new();
                    lq.symbols(&mut syms);
                    match grad {
                        GradTarget::DQ => {
                            assert!(syms.contains(&"block_idx".to_string()), "{grad}: {lq}")
                        }
                        _ => assert!(syms.contains(&"i".to_string()), "{grad}: {lq}"),
                    }
                }
            });
            assert!(saw, "{grad}: causal backward must mask the recomputed scores");
        }
    }

    #[test]
    fn reshape_precedes_every_backward_accumulate_gemm() {
        let spec = bwd_spec(true);
        for (grad, sk) in backward_sketches(&spec) {
            let r = reason(&sk, &spec, &GpuArch::a100(), &LlmProfile::deepseek_v3());
            let mut found = false;
            r.program.walk(|s| {
                if let Stmt::For { body, .. } = s {
                    for w in body.windows(2) {
                        if let (
                            Stmt::Reshape { from, to, .. },
                            Stmt::Compute { op: ComputeOp::Gemm, accumulate: true, .. },
                        ) = (&w[0], &w[1])
                        {
                            assert_eq!(from.frag, Frag::C);
                            assert_eq!(to.frag, Frag::A);
                            found = true;
                        }
                    }
                }
            });
            assert!(found, "{grad}: missing mma_C -> mma_A relayout");
        }
    }

    #[test]
    fn paged_backward_gathers_kv_on_both_sides() {
        let spec = bwd_spec(true).with_layout(KvLayout::Paged { page_size: 16 });
        for (grad, sk) in backward_sketches(&spec) {
            let r = reason(&sk, &spec, &GpuArch::a100(), &LlmProfile::deepseek_v3());
            assert!(r.program.params().contains_key("page_size"), "{grad}");
            let mut kv_gathers = 0;
            r.program.walk(|s| {
                if let Stmt::Copy { tensor, coord, src: MemSpace::Global, .. } = s {
                    let gathered = coord.iter().any(|(_, e)| e.gather().is_some());
                    if tensor == "K" || tensor == "V" {
                        assert!(gathered, "{grad}: paged {tensor} copy must gather");
                        kv_gathers += 1;
                    } else {
                        assert!(!gathered, "{grad}: {tensor} stays dense");
                    }
                }
            });
            assert!(kv_gathers >= 1, "{grad}");
        }
    }

    #[test]
    fn sliding_backward_emits_window_mask_and_guard() {
        let spec = bwd_spec(true).with_layout(KvLayout::Sliding { window: 128 });
        for (grad, sk) in backward_sketches(&spec) {
            let r = reason(&sk, &spec, &GpuArch::a100(), &LlmProfile::deepseek_v3());
            assert_eq!(r.program.params().get("window"), Some(&128), "{grad}");
            let mut saw_mask = false;
            let mut saw_guard = false;
            r.program.walk(|s| match s {
                Stmt::Compute { op: ComputeOp::WindowMask, .. } => saw_mask = true,
                Stmt::If { lhs, rhs, body, .. } => {
                    let mut syms = Vec::new();
                    lhs.symbols(&mut syms);
                    rhs.symbols(&mut syms);
                    if syms.contains(&"window".to_string())
                        && body.iter().any(|b| matches!(b, Stmt::Compute { .. }))
                    {
                        saw_guard = true;
                    }
                }
                _ => {}
            });
            assert!(saw_mask, "{grad}: sliding backward must window-mask");
            assert!(saw_guard, "{grad}: sliding backward must tile-skip");
        }
    }

    #[test]
    fn dq_prefetches_dk_dv_do_not() {
        let spec = bwd_spec(true);
        for (grad, sk) in backward_sketches(&spec) {
            let r = reason(&sk, &spec, &GpuArch::a100(), &LlmProfile::deepseek_v3());
            let mut prefetches = 0;
            r.program.walk(|s| {
                if let Stmt::If { body, .. } = s {
                    if body.iter().any(|b| matches!(b, Stmt::Copy { .. })) {
                        prefetches += 1;
                    }
                }
            });
            match grad {
                GradTarget::DQ => assert!(prefetches >= 1, "dQ must double-buffer K/V"),
                _ => assert_eq!(prefetches, 0, "{grad} stays single-buffered"),
            }
        }
    }

    #[test]
    fn backward_passes_the_static_checker() {
        for causal in [false, true] {
            let spec = bwd_spec(causal);
            for (grad, sk) in backward_sketches(&spec) {
                let r = reason(&sk, &spec, &GpuArch::a100(), &LlmProfile::deepseek_v3());
                let diags = crate::verify::checker::check(&r.program);
                assert!(diags.is_empty(), "{grad} causal={causal}: {diags:?}");
            }
        }
    }

    #[test]
    fn generate_sketch_on_backward_spec_reasons_to_dq() {
        let spec = bwd_spec(true);
        let sk = generate_sketch(&spec);
        let r = reason(&sk, &spec, &GpuArch::a100(), &LlmProfile::deepseek_v3());
        assert!(r.program.name.ends_with("_bwd_dq"), "{}", r.program.name);
    }
}
