//! The collector: the process-wide sink for closed spans and the
//! counter/gauge registry. One [`Collector`] lives for the process
//! (lazily created by [`global`]); everything it holds is cheap enough
//! to keep around whether or not tracing is enabled — a span is only
//! *recorded* when a guard closes, and counters/gauges are plain
//! relaxed atomics that cost one instruction to bump.
//!
//! Span timestamps are microseconds relative to the collector's origin
//! `Instant` (captured at first touch), which is exactly the timebase
//! Chrome `trace_event` JSON wants. Thread ids are small sequential
//! integers handed out on first use per OS thread, so traces stay
//! readable (`tid: 3`, not a 64-bit hash).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Instant;

use super::export::{Sample, SampleKind};

/// Poisoned-lock-tolerant lock: the collector only holds plain data, so
/// a panicking recorder cannot leave it in a broken state.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// One closed span, ready for export. Produced by the guards in
/// [`super::span`]; timestamps are µs since the collector origin.
#[derive(Debug, Clone)]
pub struct SpanRecord {
    /// Span name, e.g. `pipeline.verify` or `serve.execute`.
    pub name: String,
    /// Coarse category (`pipeline`, `engine`, `serve`, ...) — becomes
    /// the Chrome `cat` field so Perfetto can filter by layer.
    pub cat: &'static str,
    /// Unique id (per process, never reused).
    pub id: u64,
    /// Id of the enclosing span, if any.
    pub parent: Option<u64>,
    /// Small sequential id of the recording OS thread.
    pub tid: u64,
    /// Start, µs since the collector origin.
    pub start_us: u64,
    /// Wall duration in µs (saturating).
    pub dur_us: u64,
}

/// Handle to a monotonically increasing counter in the registry.
/// Cloning is cheap (an `Arc` bump); updates are relaxed atomics.
#[derive(Debug, Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Add one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Handle to a point-in-time gauge (queue depth, pool residency).
#[derive(Debug, Clone)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    /// Overwrite the value.
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Adjust by a signed delta.
    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Process-wide span sink and metric registry. See the module docs for
/// the cost model; [`global`] returns the shared instance.
pub struct Collector {
    origin: Instant,
    spans: Mutex<Vec<SpanRecord>>,
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<String, Arc<AtomicI64>>>,
    next_id: AtomicU64,
}

impl Collector {
    /// Fresh collector with its origin pinned to "now". Tests construct
    /// their own; production code uses [`global`].
    pub fn new() -> Self {
        Collector {
            origin: Instant::now(),
            spans: Mutex::new(Vec::new()),
            counters: Mutex::new(BTreeMap::new()),
            gauges: Mutex::new(BTreeMap::new()),
            next_id: AtomicU64::new(1),
        }
    }

    /// Allocate the next span id (ids start at 1; 0 is the inert id).
    pub fn next_span_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Microseconds from the collector origin to `t`, saturating to 0
    /// for instants before the origin and to `u64::MAX` far beyond it.
    pub fn us_since_origin(&self, t: Instant) -> u64 {
        u64::try_from(t.duration_since(self.origin).as_micros()).unwrap_or(u64::MAX)
    }

    /// Append a closed span.
    pub fn record(&self, span: SpanRecord) {
        lock(&self.spans).push(span);
    }

    /// Counter handle for `name`, created on first use. Names follow
    /// Prometheus conventions (`qimeng_requests_total`, optionally with
    /// a `{label="v"}` suffix that the exposition emits verbatim).
    pub fn counter(&self, name: &str) -> Counter {
        let mut reg = lock(&self.counters);
        Counter(Arc::clone(reg.entry(name.to_string()).or_default()))
    }

    /// Gauge handle for `name`, created on first use.
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut reg = lock(&self.gauges);
        Gauge(Arc::clone(reg.entry(name.to_string()).or_default()))
    }

    /// Snapshot of every closed span so far (clone; recording continues).
    pub fn spans(&self) -> Vec<SpanRecord> {
        lock(&self.spans).clone()
    }

    /// Drain all closed spans, leaving the sink empty.
    pub fn take_spans(&self) -> Vec<SpanRecord> {
        std::mem::take(&mut *lock(&self.spans))
    }

    /// Drop all spans and zero every registered counter and gauge (the
    /// handles stay valid). Used by tests and `tlc profile` to isolate
    /// a run.
    pub fn clear(&self) {
        lock(&self.spans).clear();
        for v in lock(&self.counters).values() {
            v.store(0, Ordering::Relaxed);
        }
        for v in lock(&self.gauges).values() {
            v.store(0, Ordering::Relaxed);
        }
    }

    /// Current value of every registered counter and gauge, in
    /// registry (name) order, ready for the Prometheus exposition.
    pub fn samples(&self) -> Vec<Sample> {
        let mut out = Vec::new();
        for (name, v) in lock(&self.counters).iter() {
            out.push(Sample {
                name: name.clone(),
                kind: SampleKind::Counter,
                value: v.load(Ordering::Relaxed) as f64,
            });
        }
        for (name, v) in lock(&self.gauges).iter() {
            out.push(Sample {
                name: name.clone(),
                kind: SampleKind::Gauge,
                value: v.load(Ordering::Relaxed) as f64,
            });
        }
        out
    }
}

impl Default for Collector {
    fn default() -> Self {
        Collector::new()
    }
}

/// The process-wide collector, created on first touch.
pub fn global() -> &'static Collector {
    static GLOBAL: OnceLock<Collector> = OnceLock::new();
    GLOBAL.get_or_init(Collector::new)
}

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

/// Small sequential id of the calling OS thread (stable for the
/// thread's lifetime; handed out on first use).
pub fn current_tid() -> u64 {
    TID.with(|t| *t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn counters_and_gauges_roundtrip() {
        let c = Collector::new();
        let n = c.counter("n_total");
        n.inc();
        n.add(4);
        assert_eq!(n.get(), 5);
        // Same name -> same underlying cell.
        assert_eq!(c.counter("n_total").get(), 5);
        let g = c.gauge("depth");
        g.set(7);
        g.add(-2);
        assert_eq!(g.get(), 5);
        let s = c.samples();
        assert_eq!(s.len(), 2);
        assert_eq!(s[0].name, "n_total");
        assert_eq!(s[0].value, 5.0);
        c.clear();
        assert_eq!(n.get(), 0);
        assert_eq!(g.get(), 0);
    }

    #[test]
    fn span_sink_take_and_snapshot() {
        let c = Collector::new();
        c.record(SpanRecord {
            name: "a".into(),
            cat: "test",
            id: c.next_span_id(),
            parent: None,
            tid: current_tid(),
            start_us: 0,
            dur_us: 10,
        });
        assert_eq!(c.spans().len(), 1);
        assert_eq!(c.take_spans().len(), 1);
        assert!(c.spans().is_empty());
    }

    #[test]
    fn origin_timebase_saturates() {
        let c = Collector::new();
        std::thread::sleep(Duration::from_millis(1));
        assert!(c.us_since_origin(Instant::now()) >= 1000);
        // An instant at/before the origin clamps to zero, never panics.
        assert_eq!(c.us_since_origin(c.origin), 0);
    }

    #[test]
    fn tids_are_small_and_distinct() {
        let here = current_tid();
        let there = std::thread::spawn(current_tid).join().unwrap();
        assert_ne!(here, there);
    }
}
