//! Per-op-kind engine profiling: wall time, touched bytes and call
//! counts attributed to coarse op kinds (gather / load / store / GEMM /
//! softmax / mask / epilogue) by the compiled TL engine's opt-in
//! profiling mode, plus the modeled-share comparison against
//! [`crate::perfmodel::cost`].
//!
//! Aggregation is lock-free by construction: each `std::thread::scope`
//! worker owns a private [`OpProfile`] and the host [`OpProfile::merge`]s
//! them after join — no atomics in the per-op hot path, just two
//! `Instant::now()` calls around each executed op.
//!
//! The observed/modeled comparison is deliberately a comparison of
//! **time shares**, not absolute times: the compiled engine runs on CPU
//! while the cost model prices a GPU, so absolute seconds are
//! incommensurable, but the *distribution* of time across op kinds is
//! exactly what the model's per-term structure predicts and where its
//! errors show up (DESIGN.md §11).

use std::time::Duration;

use crate::perfmodel::cost::{self, Schedule};
use crate::perfmodel::gpu::GpuArch;
use crate::sketch::spec::{KvLayout, OpSpec};

/// Coarse op kind the engine attributes time and bytes to. The mapping
/// from concrete engine ops lives next to the engine
/// (`verify::compiled`); softmax covers the row-stats family
/// (exp / row-max / row-sum / online and local softmax).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum OpKind {
    /// Block-table-indexed page gather loads (paged KV).
    Gather,
    /// Contiguous tile loads.
    Load,
    /// Tile stores to the output.
    Store,
    /// Matrix multiplies (including their fused epilogues).
    Gemm,
    /// Softmax family: exp, row-max/row-sum, online/local softmax.
    Softmax,
    /// Causal and sliding-window masking.
    Mask,
    /// Everything else: zeroing, moves, pointwise maps, rescales.
    Epilogue,
}

/// Number of op kinds (array dimension of [`OpProfile`]).
pub const N_KINDS: usize = 7;

impl OpKind {
    /// All kinds, in display order.
    pub const ALL: [OpKind; N_KINDS] = [
        OpKind::Gather,
        OpKind::Load,
        OpKind::Store,
        OpKind::Gemm,
        OpKind::Softmax,
        OpKind::Mask,
        OpKind::Epilogue,
    ];

    /// Lower-case display name.
    pub fn as_str(self) -> &'static str {
        match self {
            OpKind::Gather => "gather",
            OpKind::Load => "load",
            OpKind::Store => "store",
            OpKind::Gemm => "gemm",
            OpKind::Softmax => "softmax",
            OpKind::Mask => "mask",
            OpKind::Epilogue => "epilogue",
        }
    }

    fn idx(self) -> usize {
        match self {
            OpKind::Gather => 0,
            OpKind::Load => 1,
            OpKind::Store => 2,
            OpKind::Gemm => 3,
            OpKind::Softmax => 4,
            OpKind::Mask => 5,
            OpKind::Epilogue => 6,
        }
    }
}

/// Accumulated per-kind wall time (ns), touched bytes and op counts
/// for one profiled engine run (or one worker's share of it).
#[derive(Debug, Clone)]
pub struct OpProfile {
    ns: [u64; N_KINDS],
    bytes: [u64; N_KINDS],
    count: [u64; N_KINDS],
    blocks: u64,
}

impl OpProfile {
    /// Empty profile.
    pub fn new() -> Self {
        OpProfile {
            ns: [0; N_KINDS],
            bytes: [0; N_KINDS],
            count: [0; N_KINDS],
            blocks: 0,
        }
    }

    /// Attribute one executed op.
    pub fn record(&mut self, kind: OpKind, elapsed: Duration, bytes: u64) {
        let i = kind.idx();
        self.ns[i] = self.ns[i]
            .saturating_add(u64::try_from(elapsed.as_nanos()).unwrap_or(u64::MAX));
        self.bytes[i] = self.bytes[i].saturating_add(bytes);
        self.count[i] += 1;
    }

    /// Count one executed q-block.
    pub fn add_block(&mut self) {
        self.blocks += 1;
    }

    /// Fold another profile (typically a worker's) into this one.
    pub fn merge(&mut self, other: &OpProfile) {
        for i in 0..N_KINDS {
            self.ns[i] = self.ns[i].saturating_add(other.ns[i]);
            self.bytes[i] = self.bytes[i].saturating_add(other.bytes[i]);
            self.count[i] += other.count[i];
        }
        self.blocks += other.blocks;
    }

    /// Summed wall time across all kinds, ns.
    pub fn total_ns(&self) -> u64 {
        self.ns.iter().fold(0u64, |a, &b| a.saturating_add(b))
    }

    /// Wall time attributed to `kind`, ns.
    pub fn ns_of(&self, kind: OpKind) -> u64 {
        self.ns[kind.idx()]
    }

    /// Bytes attributed to `kind`.
    pub fn bytes_of(&self, kind: OpKind) -> u64 {
        self.bytes[kind.idx()]
    }

    /// Ops attributed to `kind`.
    pub fn count_of(&self, kind: OpKind) -> u64 {
        self.count[kind.idx()]
    }

    /// Q-blocks executed.
    pub fn blocks(&self) -> u64 {
        self.blocks
    }

    /// True when nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.count.iter().all(|&c| c == 0)
    }

    /// Render the per-kind breakdown as an aligned text table.
    pub fn table(&self) -> String {
        let total = self.total_ns().max(1) as f64;
        let mut out = String::new();
        out.push_str(&format!(
            "  {:<10} {:>10} {:>12} {:>7} {:>12} {:>10}\n",
            "op-kind", "calls", "time", "share", "bytes", "GB/s"
        ));
        for kind in OpKind::ALL {
            if self.count_of(kind) == 0 {
                continue;
            }
            let ns = self.ns_of(kind);
            let bytes = self.bytes_of(kind);
            let gbs = if ns > 0 { bytes as f64 / ns as f64 } else { 0.0 };
            out.push_str(&format!(
                "  {:<10} {:>10} {:>12} {:>6.1}% {:>12} {:>10.2}\n",
                kind.as_str(),
                self.count_of(kind),
                fmt_ns(ns),
                100.0 * ns as f64 / total,
                bytes,
                gbs,
            ));
        }
        out.push_str(&format!(
            "  {:<10} {:>10} {:>12} {:>6.1}%   ({} blocks)\n",
            "total",
            self.count.iter().sum::<u64>(),
            fmt_ns(self.total_ns()),
            100.0,
            self.blocks,
        ));
        out
    }
}

impl Default for OpProfile {
    fn default() -> Self {
        OpProfile::new()
    }
}

fn fmt_ns(ns: u64) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.2}s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.1}us", ns as f64 / 1e3)
    } else {
        format!("{ns}ns")
    }
}

/// Modeled wall-time per op kind (seconds on the modeled GPU) for one
/// (spec, arch, schedule) cell, decomposed from the same terms
/// [`cost::estimate`] prices: tensor-core GEMM time, CUDA-core softmax
/// and mask time, and DRAM stream time split across Q/output
/// (load/store) and the KV stream (gather under a paged layout, load
/// otherwise). Kinds the model prices at zero are omitted.
pub fn modeled_kinds(spec: &OpSpec, arch: &GpuArch, sched: &Schedule) -> Vec<(OpKind, f64)> {
    let est = cost::estimate(spec, arch, sched);
    let bw = arch.mem_bw_gbs * 1e9;
    let bh = (spec.batch * spec.num_q_heads) as f64;
    let s = spec.seq_len as f64;
    let kv = spec.kv_len as f64;
    let el = spec.dtype.bytes() as f64;
    let visited = if spec.causal && sched.causal_block_skip { 0.5 } else { 1.0 };
    let score = bh * s * kv * visited;
    let cuda = arch.cuda_tflops_f32 * 1e12;

    let peak = if sched.tensor_core {
        arch.tc_tflops(spec.dtype.bytes()) * 1e12
    } else {
        cuda
    };
    let t_gemm = spec.flops() / (peak * sched.mma_eff.max(1e-6));
    // Softmax ops per visited score element, after pipeline overlap; the
    // mask is priced separately (2 ops/elem when causal).
    let t_softmax = 5.0 * score / cuda * (1.0 - sched.softmax_overlap);
    let t_mask = if spec.causal { 2.0 * score / cuda } else { 0.0 };

    let q_bytes = bh * s * spec.qk_dim() as f64 * el;
    let o_bytes = bh * s * spec.v_head_dim as f64 * el;
    let total_bytes = est.dram_gb * 1e9;
    let kv_stream = (total_bytes - q_bytes - o_bytes).max(0.0);
    let t_store = o_bytes / bw;
    let (t_load, t_gather) = match spec.kv_layout {
        KvLayout::Paged { .. } => (q_bytes / bw, kv_stream / bw),
        _ => ((q_bytes + kv_stream) / bw, 0.0),
    };
    // Prologue/epilogue overhead, in units of KV-tile iterations.
    let nkv = (kv * visited / sched.bn.max(1) as f64).max(1.0);
    let t_epi = sched.c_epi / nkv * (t_gemm + t_softmax);

    [
        (OpKind::Gather, t_gather),
        (OpKind::Load, t_load),
        (OpKind::Store, t_store),
        (OpKind::Gemm, t_gemm),
        (OpKind::Softmax, t_softmax),
        (OpKind::Mask, t_mask),
        (OpKind::Epilogue, t_epi),
    ]
    .into_iter()
    .filter(|&(_, t)| t > 0.0)
    .collect()
}

/// How far the observed and modeled shares may drift (in absolute
/// percentage points of total time) before a kind is flagged.
pub const DISAGREE_POINTS: f64 = 15.0;

/// Render the op-level observed-vs-modeled disagreement table: one row
/// per kind carrying the observed (CPU engine) and modeled (GPU cost
/// model) shares of total time. Shares, not absolute times, are
/// compared — see the module docs. A kind is flagged `DISAGREE` when
/// the shares drift more than [`DISAGREE_POINTS`] points and either
/// side is above 5%.
pub fn disagreement_table(observed: &OpProfile, modeled: &[(OpKind, f64)]) -> String {
    let obs_total = observed.total_ns().max(1) as f64;
    let mod_total: f64 = modeled.iter().map(|&(_, t)| t).sum();
    let mod_total = if mod_total > 0.0 { mod_total } else { 1.0 };
    let mod_share = |kind: OpKind| -> f64 {
        modeled
            .iter()
            .find(|&&(k, _)| k == kind)
            .map(|&(_, t)| 100.0 * t / mod_total)
            .unwrap_or(0.0)
    };
    let mut out = String::new();
    out.push_str(&format!(
        "  {:<10} {:>12} {:>8} {:>8} {:>8}  verdict\n",
        "op-kind", "observed", "obs%", "model%", "drift"
    ));
    for kind in OpKind::ALL {
        let obs_ns = observed.ns_of(kind);
        let obs_pct = 100.0 * obs_ns as f64 / obs_total;
        let mod_pct = mod_share(kind);
        if obs_ns == 0 && mod_pct == 0.0 {
            continue;
        }
        let drift = obs_pct - mod_pct;
        let verdict = if drift.abs() > DISAGREE_POINTS && obs_pct.max(mod_pct) > 5.0 {
            "DISAGREE"
        } else {
            "agree"
        };
        out.push_str(&format!(
            "  {:<10} {:>12} {:>7.1}% {:>7.1}% {:>+7.1}p  {verdict}\n",
            kind.as_str(),
            fmt_ns(obs_ns),
            obs_pct,
            mod_pct,
            drift,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::schedules;
    use crate::sketch::spec::AttnVariant;

    #[test]
    fn record_and_merge_accumulate() {
        let mut a = OpProfile::new();
        a.record(OpKind::Gemm, Duration::from_micros(10), 4096);
        a.record(OpKind::Gemm, Duration::from_micros(5), 2048);
        a.add_block();
        let mut b = OpProfile::new();
        b.record(OpKind::Softmax, Duration::from_micros(3), 512);
        b.add_block();
        a.merge(&b);
        assert_eq!(a.count_of(OpKind::Gemm), 2);
        assert_eq!(a.ns_of(OpKind::Gemm), 15_000);
        assert_eq!(a.bytes_of(OpKind::Gemm), 6144);
        assert_eq!(a.count_of(OpKind::Softmax), 1);
        assert_eq!(a.blocks(), 2);
        assert_eq!(a.total_ns(), 18_000);
        assert!(!a.is_empty());
        let t = a.table();
        assert!(t.contains("gemm"), "{t}");
        assert!(t.contains("softmax"), "{t}");
    }

    #[test]
    fn record_saturates_on_pathological_durations() {
        let mut p = OpProfile::new();
        p.record(OpKind::Load, Duration::MAX, u64::MAX);
        p.record(OpKind::Load, Duration::from_nanos(1), 1);
        assert_eq!(p.ns_of(OpKind::Load), u64::MAX);
        assert_eq!(p.bytes_of(OpKind::Load), u64::MAX);
        assert_eq!(p.count_of(OpKind::Load), 2);
    }

    #[test]
    fn modeled_kinds_cover_the_fused_terms() {
        let spec = OpSpec::benchmark(AttnVariant::Mha, 1024, 64, true);
        let arch = GpuArch::a100();
        let sched = schedules::ours(&arch, 64, spec.dtype);
        let kinds = modeled_kinds(&spec, &arch, &sched);
        let names: Vec<OpKind> = kinds.iter().map(|&(k, _)| k).collect();
        assert!(names.contains(&OpKind::Gemm));
        assert!(names.contains(&OpKind::Softmax));
        assert!(names.contains(&OpKind::Mask), "causal spec must price the mask");
        assert!(names.contains(&OpKind::Load));
        assert!(names.contains(&OpKind::Store));
        assert!(!names.contains(&OpKind::Gather), "contiguous spec has no gather");
        assert!(kinds.iter().all(|&(_, t)| t.is_finite() && t > 0.0));
    }

    #[test]
    fn paged_spec_moves_kv_stream_to_gather() {
        let spec = OpSpec::benchmark(AttnVariant::Mha, 1024, 64, true)
            .with_layout(KvLayout::Paged { page_size: 16 });
        let arch = GpuArch::a100();
        let sched = schedules::ours(&arch, 64, spec.dtype);
        let kinds = modeled_kinds(&spec, &arch, &sched);
        let of = |k: OpKind| kinds.iter().find(|&&(x, _)| x == k).map(|&(_, t)| t);
        let gather = of(OpKind::Gather).expect("paged spec prices the gather");
        assert!(gather > of(OpKind::Load).unwrap_or(0.0), "KV stream dominates Q load");
    }

    #[test]
    fn disagreement_table_flags_large_drift() {
        let mut obs = OpProfile::new();
        // Observed: all time in softmax.
        obs.record(OpKind::Softmax, Duration::from_millis(10), 1024);
        // Modeled: all time in GEMM.
        let modeled = vec![(OpKind::Gemm, 1.0)];
        let t = disagreement_table(&obs, &modeled);
        assert!(t.contains("DISAGREE"), "{t}");
        // Concordant shares stay quiet.
        let modeled = vec![(OpKind::Softmax, 1.0)];
        let t = disagreement_table(&obs, &modeled);
        assert!(!t.contains("DISAGREE"), "{t}");
    }
}
