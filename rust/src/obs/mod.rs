//! Unified observability: structured spans, op-level engine profiling
//! and exportable metrics across the pipeline, the compiled TL engine
//! and the serving coordinator (DESIGN.md §11).
//!
//! Three instruments, one collector:
//!
//! * **Spans** ([`span`], [`span_cat`], [`span_under`]) — RAII guards
//!   with parent/child nesting that works across `std::thread::scope`
//!   workers via [`SpanCtx`]. The pipeline wraps each stage
//!   (`pipeline.sketch` … `pipeline.translate`) in a span whose
//!   [`SpanGuard::finish`] return value still populates
//!   [`crate::pipeline::Timings`]; the serving coordinator emits the
//!   request lifecycle (`serve.request`, `serve.plan`, `serve.admit`,
//!   `serve.execute`, `serve.respond`).
//! * **Counters and gauges** ([`counter`], [`gauge`]) — a registry of
//!   relaxed atomics unifying the ad-hoc [`crate::coordinator::Metrics`]
//!   fields with per-lane queue depths and KV-pool residency.
//! * **Op profiles** ([`profile::OpProfile`]) — opt-in per-op-kind
//!   wall-time/bytes attribution inside the compiled engine, aggregated
//!   lock-free per worker, compared against [`crate::perfmodel::cost`]
//!   in `tlc tune --report` and `tlc profile`.
//!
//! Exporters ([`export::chrome_trace`], [`export::prometheus_text`])
//! serve Perfetto / `chrome://tracing` and Prometheus scrapes; `tlc
//! serve --metrics-out --trace-out` and `tlc profile` write them.
//!
//! **Cost when disabled** (the default): opening a span is one
//! `Instant::now()` and one relaxed atomic load; counters and gauges
//! are single relaxed atomic ops; the engine's profiling mode is a
//! separate entry point that normal execution never touches. Nothing
//! allocates and nothing locks until [`set_enabled`]`(true)`.

use std::sync::atomic::{AtomicBool, Ordering};

pub mod collect;
pub mod export;
pub mod profile;
pub mod span;

pub use collect::{global, Collector, Counter, Gauge, SpanRecord};
pub use profile::{OpKind, OpProfile};
pub use span::{record_closed, span, span_cat, span_under, SpanCtx, SpanGuard};

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Turn span recording on or off process-wide. Metrics handles keep
/// working either way (they are plain atomics); only span *recording*
/// is gated.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Is span recording on?
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Counter handle from the global registry (created on first use).
pub fn counter(name: &str) -> Counter {
    global().counter(name)
}

/// Gauge handle from the global registry (created on first use).
pub fn gauge(name: &str) -> Gauge {
    global().gauge(name)
}
