//! Exporters (and the matching parsers the tests gate on): Chrome
//! `trace_event` JSON — loadable in Perfetto / `chrome://tracing` — and
//! Prometheus text exposition. Both formats are simple enough to emit
//! and parse by hand, which keeps the vendored-offline discipline (no
//! serde) and gives the schema tests a real parse-back, not a substring
//! check.

use std::collections::BTreeSet;

use super::collect::SpanRecord;

/// Metric family kind, mirrored in the `# TYPE` exposition line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SampleKind {
    /// Monotonically increasing count.
    Counter,
    /// Point-in-time value.
    Gauge,
}

impl SampleKind {
    /// Prometheus spelling of the kind.
    pub fn as_str(self) -> &'static str {
        match self {
            SampleKind::Counter => "counter",
            SampleKind::Gauge => "gauge",
        }
    }
}

/// One exported metric value. `name` may carry a `{label="v"}` suffix,
/// emitted verbatim; the `# TYPE` line uses the base name before `{`.
#[derive(Debug, Clone)]
pub struct Sample {
    /// Metric name, optionally with a label set suffix.
    pub name: String,
    /// Counter or gauge.
    pub kind: SampleKind,
    /// Current value.
    pub value: f64,
}

/// Render spans as Chrome `trace_event` JSON: one complete (`ph: "X"`)
/// event per span, timestamps/durations in µs, span ids and parents in
/// `args`. Load the output in Perfetto or `chrome://tracing`.
pub fn chrome_trace(spans: &[SpanRecord]) -> String {
    let mut out = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    for (i, s) in spans.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let parent = match s.parent {
            Some(p) => format!(",\"parent\":{p}"),
            None => String::new(),
        };
        out.push_str(&format!(
            "{{\"name\":{},\"cat\":{},\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":1,\"tid\":{},\"args\":{{\"id\":{}{}}}}}",
            json_str(&s.name),
            json_str(s.cat),
            s.start_us,
            s.dur_us,
            s.tid,
            s.id,
            parent,
        ));
    }
    out.push_str("]}");
    out
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Render samples in the Prometheus text exposition format: a `# TYPE`
/// line per metric family (base name before any `{`), then one
/// `name value` line per sample.
pub fn prometheus_text(samples: &[Sample]) -> String {
    let mut out = String::new();
    let mut typed: BTreeSet<&str> = BTreeSet::new();
    for s in samples {
        let base = s.name.split('{').next().unwrap_or(&s.name);
        if typed.insert(base) {
            out.push_str(&format!("# TYPE {base} {}\n", s.kind.as_str()));
        }
        out.push_str(&format!("{} {}\n", s.name, s.value));
    }
    out
}

/// Parse a Prometheus text exposition back into `(name, value)` pairs
/// (comment and blank lines skipped). The inverse of
/// [`prometheus_text`] up to value formatting.
pub fn parse_prometheus(text: &str) -> Result<Vec<(String, f64)>, String> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (name, value) = line
            .rsplit_once(char::is_whitespace)
            .ok_or_else(|| format!("line {}: no value: {line:?}", lineno + 1))?;
        let value: f64 = value
            .parse()
            .map_err(|e| format!("line {}: bad value {value:?}: {e}", lineno + 1))?;
        out.push((name.trim().to_string(), value));
    }
    Ok(out)
}

/// Minimal JSON value, for schema-checking exported traces without a
/// serde dependency.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (parsed as f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object, insertion-ordered.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
}

/// Parse a JSON document (recursive descent; enough of RFC 8259 for
/// trace files: no depth limit, `\uXXXX` decoded, numbers via `f64`).
pub fn parse_json(s: &str) -> Result<Json, String> {
    let mut p = Parser { b: s.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing bytes at offset {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at offset {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at offset {}", self.i)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at offset {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {text:?} at offset {start}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|e| e.to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|e| format!("bad \\u escape {hex:?}: {e}"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy one UTF-8 scalar (strings arrive validated).
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| e.to_string())?;
                    let ch = rest.chars().next().ok_or("empty string tail")?;
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("expected , or ] at offset {}: {other:?}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            out.push((key, val));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("expected , or }} at offset {}: {other:?}", self.i)),
            }
        }
    }
}

/// One row of a per-span-name aggregation (the `tlc profile` breakdown
/// table).
#[derive(Debug, Clone)]
pub struct RollupRow {
    /// Span name.
    pub name: String,
    /// How many spans closed under this name.
    pub count: u64,
    /// Summed wall time, µs.
    pub total_us: u64,
    /// Longest single span, µs.
    pub max_us: u64,
}

/// Aggregate spans by name, sorted by total time descending (ties by
/// name, so the table is deterministic).
pub fn rollup(spans: &[SpanRecord]) -> Vec<RollupRow> {
    let mut by_name: std::collections::BTreeMap<&str, RollupRow> =
        std::collections::BTreeMap::new();
    for s in spans {
        let row = by_name.entry(&s.name).or_insert_with(|| RollupRow {
            name: s.name.clone(),
            count: 0,
            total_us: 0,
            max_us: 0,
        });
        row.count += 1;
        row.total_us = row.total_us.saturating_add(s.dur_us);
        row.max_us = row.max_us.max(s.dur_us);
    }
    let mut rows: Vec<RollupRow> = by_name.into_values().collect();
    rows.sort_by(|a, b| b.total_us.cmp(&a.total_us).then(a.name.cmp(&b.name)));
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(name: &str, parent: Option<u64>, id: u64, start: u64, dur: u64) -> SpanRecord {
        SpanRecord {
            name: name.into(),
            cat: "test",
            id,
            parent,
            tid: 1,
            start_us: start,
            dur_us: dur,
        }
    }

    #[test]
    fn chrome_trace_parses_back() {
        let spans =
            vec![span("outer \"x\"", None, 1, 0, 100), span("inner", Some(1), 2, 10, 50)];
        let doc = parse_json(&chrome_trace(&spans)).expect("valid JSON");
        let events = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents");
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].get("name").and_then(Json::as_str), Some("outer \"x\""));
        assert_eq!(events[0].get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(events[1].get("dur").and_then(Json::as_f64), Some(50.0));
        let args = events[1].get("args").expect("args");
        assert_eq!(args.get("parent").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn prometheus_roundtrip() {
        let samples = vec![
            Sample { name: "a_total".into(), kind: SampleKind::Counter, value: 3.0 },
            Sample {
                name: "depth{shard=\"0\"}".into(),
                kind: SampleKind::Gauge,
                value: 2.5,
            },
            Sample {
                name: "depth{shard=\"1\"}".into(),
                kind: SampleKind::Gauge,
                value: 4.0,
            },
        ];
        let text = prometheus_text(&samples);
        // One TYPE line per family, not per labeled sample.
        assert_eq!(text.matches("# TYPE depth gauge").count(), 1);
        let parsed = parse_prometheus(&text).expect("parses");
        assert_eq!(parsed.len(), 3);
        assert_eq!(parsed[1], ("depth{shard=\"0\"}".to_string(), 2.5));
    }

    #[test]
    fn json_parser_handles_escapes_and_nesting() {
        let v = parse_json(r#"{"a":[1,-2.5e1,"xA\n"],"b":{"c":null,"d":true}}"#)
            .expect("parses");
        let arr = v.get("a").and_then(Json::as_arr).unwrap();
        assert_eq!(arr[1].as_f64(), Some(-25.0));
        assert_eq!(arr[2].as_str(), Some("xA\n"));
        assert_eq!(v.get("b").unwrap().get("c"), Some(&Json::Null));
        assert!(parse_json("{\"a\":1,}").is_err());
        assert!(parse_json("[1 2]").is_err());
    }

    #[test]
    fn rollup_aggregates_and_sorts() {
        let spans = vec![
            span("b", None, 1, 0, 10),
            span("a", None, 2, 0, 5),
            span("b", None, 3, 20, 30),
        ];
        let rows = rollup(&spans);
        assert_eq!(rows[0].name, "b");
        assert_eq!(rows[0].count, 2);
        assert_eq!(rows[0].total_us, 40);
        assert_eq!(rows[0].max_us, 30);
        assert_eq!(rows[1].name, "a");
    }
}
