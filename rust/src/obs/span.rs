//! RAII span guards with parent/child nesting — including across
//! `std::thread::scope` workers.
//!
//! Within one thread, nesting is implicit: a thread-local stack of open
//! span ids makes the innermost open span the parent of the next one.
//! Across threads the stack cannot help (each worker starts with an
//! empty stack), so a guard exposes a [`SpanCtx`] — a `Copy` capture of
//! its id — that the host passes into worker closures and the worker
//! hands to [`span_under`] to adopt the host span as parent.
//!
//! Cost when tracing is disabled: a guard is one `Instant::now()` (the
//! start time is still needed because [`SpanGuard::finish`] doubles as
//! the stage timer for `pipeline::Timings`) plus one relaxed atomic
//! load; nothing is allocated and nothing touches the collector.

use std::cell::RefCell;
use std::time::{Duration, Instant};

use super::collect::{self, SpanRecord};
use super::enabled;

thread_local! {
    static STACK: RefCell<Vec<u64>> = RefCell::new(Vec::new());
}

/// A `Copy` capture of an open span's identity, for parenting spans
/// opened on *other* threads under it. [`SpanCtx::NONE`] is inert.
#[derive(Debug, Clone, Copy)]
pub struct SpanCtx(u64);

impl SpanCtx {
    /// The inert context: spans opened under it get no parent.
    pub const NONE: SpanCtx = SpanCtx(0);
}

/// An open span. Closes (and records, when tracing is enabled) on drop
/// or explicitly via [`SpanGuard::finish`], which also returns the
/// elapsed wall time so call sites can keep feeding `Timings`.
#[derive(Debug)]
pub struct SpanGuard {
    name: &'static str,
    cat: &'static str,
    id: u64,
    parent: Option<u64>,
    start: Instant,
    active: bool,
}

/// Open a span in the default category. Equivalent to
/// `span_cat(name, "span")`.
pub fn span(name: &'static str) -> SpanGuard {
    span_cat(name, "span")
}

/// Open a span under category `cat`, parented to the innermost span
/// already open on this thread (if any).
pub fn span_cat(name: &'static str, cat: &'static str) -> SpanGuard {
    open(name, cat, None)
}

/// Open a span parented to `ctx` — the cross-thread form. If this
/// thread already has an open span, that inner span wins as parent
/// (it is necessarily a descendant of `ctx`'s thread-crossing point).
pub fn span_under(name: &'static str, cat: &'static str, ctx: SpanCtx) -> SpanGuard {
    open(name, cat, if ctx.0 == 0 { None } else { Some(ctx.0) })
}

fn open(name: &'static str, cat: &'static str, cross: Option<u64>) -> SpanGuard {
    let start = Instant::now();
    if !enabled() {
        return SpanGuard { name, cat, id: 0, parent: None, start, active: false };
    }
    let id = collect::global().next_span_id();
    let parent = STACK.with(|s| {
        let mut s = s.borrow_mut();
        let local = s.last().copied();
        s.push(id);
        local.or(cross)
    });
    SpanGuard { name, cat, id, parent, start, active: true }
}

impl SpanGuard {
    /// Capture this span's identity for parenting worker-thread spans.
    pub fn ctx(&self) -> SpanCtx {
        SpanCtx(self.id)
    }

    /// Close the span now and return its elapsed wall time. The return
    /// value is measured whether or not tracing is enabled, so stage
    /// timers (`pipeline::Timings`) read it unconditionally.
    pub fn finish(mut self) -> Duration {
        let d = self.start.elapsed();
        self.close(d);
        d
    }

    fn close(&mut self, elapsed: Duration) {
        if !self.active {
            return;
        }
        self.active = false;
        STACK.with(|s| {
            let mut s = s.borrow_mut();
            // Guards are dropped innermost-first in well-formed code;
            // tolerate out-of-order drops by removing wherever we are.
            if s.last() == Some(&self.id) {
                s.pop();
            } else if let Some(pos) = s.iter().rposition(|&x| x == self.id) {
                s.remove(pos);
            }
        });
        let c = collect::global();
        c.record(SpanRecord {
            name: self.name.to_string(),
            cat: self.cat,
            id: self.id,
            parent: self.parent,
            tid: collect::current_tid(),
            start_us: c.us_since_origin(self.start),
            dur_us: u64::try_from(elapsed.as_micros()).unwrap_or(u64::MAX),
        });
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.active {
            let d = self.start.elapsed();
            self.close(d);
        }
    }
}

/// Record an interval that was measured out-of-band as a closed span
/// (no RAII, no nesting stack). Used where the start instant predates
/// any guard — e.g. a serving request's `enqueued` timestamp turned
/// into a `serve.request` span at reply time. No-op when disabled.
pub fn record_closed(name: &'static str, cat: &'static str, start: Instant, dur: Duration) {
    if !enabled() {
        return;
    }
    let c = collect::global();
    c.record(SpanRecord {
        name: name.to_string(),
        cat,
        id: c.next_span_id(),
        parent: None,
        tid: collect::current_tid(),
        start_us: c.us_since_origin(start),
        dur_us: u64::try_from(dur.as_micros()).unwrap_or(u64::MAX),
    });
}
