//! Observability overhead bench: the compiled engine swept over the
//! same attention problem in three configurations — obs disabled
//! (baseline), span tracing enabled, and the opt-in per-op profiling
//! entry point. §Obs (DESIGN.md §11) promises the layer is ~zero-cost
//! when disabled; this bench is the gate that keeps that promise.
//!
//! Modes:
//!   cargo bench --bench obs              full run
//!   cargo bench --bench obs -- --smoke   fewer samples (CI): gates on
//!       profiled-run bit-identity, tracing overhead < 2% and profiling
//!       overhead < 15% (min-of-samples ratios, baseline re-measured
//!       after the candidates to absorb machine drift), records
//!       BENCH_obs.json.

use std::collections::BTreeMap;

use qimeng::obs;
use qimeng::perfmodel::gpu::GpuArch;
use qimeng::reasoner::generate_tl_code;
use qimeng::reasoner::profiles::LlmProfile;
use qimeng::sketch::spec::{AttnVariant, OpSpec};
use qimeng::util::bench::Bench;
use qimeng::verify::exec::{run_attention_profiled, run_attention_threads};
use qimeng::verify::tensor::Tensor2;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let samples = if smoke { 7 } else { 25 };
    let mut failures: Vec<String> = Vec::new();

    let mut spec = OpSpec::benchmark(AttnVariant::Mha, 1024, 64, true);
    spec.batch = 1;
    let program = generate_tl_code(&spec, &GpuArch::a100(), &LlmProfile::deepseek_v3()).program;
    let q = Tensor2::randn(spec.seq_len, 64, 1);
    let k = Tensor2::randn(spec.kv_len, 64, 2);
    let v = Tensor2::randn(spec.kv_len, 64, 3);
    let scale = 0.125;
    let no_tables: BTreeMap<String, Vec<i64>> = BTreeMap::new();

    // Correctness gate before timing anything: the profiling mode must
    // be bit-identical to the plain sweep and actually attribute ops.
    obs::set_enabled(false);
    let want = run_attention_threads(&program, &q, &k, &v, scale, 1).unwrap();
    let (got, prof) =
        run_attention_profiled(&program, &q, &k, &v, scale, &no_tables, 1).unwrap();
    if got.data != want.data {
        failures.push("profiled sweep is not bit-identical to the plain sweep".into());
    }
    if prof.is_empty() || prof.total_ns() == 0 {
        failures.push("profiled sweep attributed no ops".into());
    }

    // Serial sweeps only: the 2% gate needs the steadiest clock we have,
    // and parallel scheduling jitter would drown it.
    let base_a = Bench::new("obs_disabled_1t")
        .warmup(2)
        .samples(samples)
        .run(|| run_attention_threads(&program, &q, &k, &v, scale, 1).unwrap());

    obs::set_enabled(true);
    obs::global().clear();
    let traced = Bench::new("obs_traced_1t")
        .warmup(2)
        .samples(samples)
        .run(|| run_attention_threads(&program, &q, &k, &v, scale, 1).unwrap());
    obs::set_enabled(false);
    obs::global().clear();

    let profiled = Bench::new("obs_profiled_1t").warmup(2).samples(samples).run(|| {
        run_attention_profiled(&program, &q, &k, &v, scale, &no_tables, 1).unwrap()
    });

    // Re-measure the baseline after the candidates: if the machine
    // slowed down mid-bench, the min of both baselines absorbs it.
    let base_b = Bench::new("obs_disabled_1t_again")
        .warmup(2)
        .samples(samples)
        .run(|| run_attention_threads(&program, &q, &k, &v, scale, 1).unwrap());

    let base_us = base_a.min.min(base_b.min).as_secs_f64() * 1e6;
    let traced_us = traced.min.as_secs_f64() * 1e6;
    let profiled_us = profiled.min.as_secs_f64() * 1e6;
    let disabled_overhead = traced_us / base_us - 1.0;
    let enabled_overhead = profiled_us / base_us - 1.0;
    println!(
        "  -> tracing overhead {:.2}% (gate 2%), profiling overhead {:.2}% (gate 15%)",
        disabled_overhead * 100.0,
        enabled_overhead * 100.0,
    );

    let json = format!(
        "{{\n  \"mode\": \"{}\",\n  \"threads\": 1,\n  \"base_us\": {base_us:.1},\n  \
         \"traced_us\": {traced_us:.1},\n  \"profiled_us\": {profiled_us:.1},\n  \
         \"disabled_overhead\": {disabled_overhead:.4},\n  \
         \"enabled_overhead\": {enabled_overhead:.4}\n}}\n",
        if smoke { "smoke" } else { "full" }
    );
    if let Err(e) = std::fs::write("BENCH_obs.json", &json) {
        eprintln!("warning: could not write BENCH_obs.json: {e}");
    } else {
        println!("recorded BENCH_obs.json:\n{json}");
    }

    // Overhead gates run in CI (smoke) only; full local runs report
    // without gating so exploratory machines don't fail spuriously.
    if smoke && disabled_overhead > 0.02 {
        failures.push(format!(
            "span tracing costs {:.2}% over the disabled baseline (cap 2%)",
            disabled_overhead * 100.0
        ));
    }
    if smoke && enabled_overhead > 0.15 {
        failures.push(format!(
            "op profiling costs {:.2}% over the disabled baseline (cap 15%)",
            enabled_overhead * 100.0
        ));
    }
    if !failures.is_empty() {
        eprintln!("obs bench FAILED:");
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
}
