//! Autotuner benches: cost of one exhaustive schedule search, the beam
//! variant, and the cache hot path that repeat pipeline runs and the
//! serving registry pay. §Perf targets: exhaustive search per spec well
//! under the 50 ms pipeline budget; cache hit effectively free (< 10 us).

use qimeng::autotune::search::{run_search, SearchStrategy};
use qimeng::autotune::{cache, space, Autotuner};
use qimeng::perfmodel::gpu::GpuArch;
use qimeng::pipeline::Target;
use qimeng::sketch::spec::{AttnVariant, OpSpec};
use qimeng::util::bench::Bench;

fn main() {
    let arch = GpuArch::a100();
    let spec = OpSpec::benchmark(AttnVariant::Mha, 16384, 128, true);

    let candidates = space::enumerate(&spec, &arch);
    println!(
        "schedule space: {} feasible candidates (mha hd128 @16k causal on {})",
        candidates.len(),
        arch.name
    );

    Bench::new("space_enumeration").samples(100).run(|| space::enumerate(&spec, &arch));

    Bench::new("exhaustive_search_one_spec").samples(50).run(|| {
        run_search(&candidates, SearchStrategy::Exhaustive, |c| {
            space::model_seconds(&spec, &arch, c)
        })
    });

    Bench::new("beam_search_one_spec").samples(50).run(|| {
        run_search(
            &candidates,
            SearchStrategy::Beam { width: 16, rounds: 12, seed: 0x5EED },
            |c| space::model_seconds(&spec, &arch, c),
        )
    });

    // Cache hot path: what a repeat pipeline run / serving lookup costs.
    let mut tuner = Autotuner::in_memory();
    tuner.tune(&spec, &arch, Target::Pallas); // populate
    let rep = Bench::new("tune_cache_hit").samples(200).run(|| {
        tuner.tune(&spec, &arch, Target::Pallas)
    });
    println!(
        "cache hit mean {:?} — 10 us target: {}",
        rep.mean,
        if rep.mean < std::time::Duration::from_micros(10) { "MET" } else { "MISSED" }
    );

    // Full-grid tuning cost (what `tlc tune --grid` pays cold).
    let grid: Vec<OpSpec> = qimeng::workload::table1_grid(true);
    Bench::new("exhaustive_grid_36_specs").samples(5).warmup(1).run(|| {
        let mut t = Autotuner::in_memory();
        for s in &grid {
            t.tune(s, &arch, Target::Pallas);
        }
        t.cache().len()
    });

    // Serialization round-trip (startup cost of a warm cache).
    let text = {
        let mut t = Autotuner::in_memory();
        for s in &grid {
            t.tune(s, &arch, Target::Pallas);
        }
        t.cache().render()
    };
    Bench::new("cache_parse_36_entries").samples(200).run(|| {
        cache::TuneCache::parse(&text).unwrap().len()
    });
}
