//! Table-regeneration benches: each paper table rendered end to end from
//! the performance model. DESIGN.md §7 target: the full Table-1 grid in
//! under 1 second.

use qimeng::report::tables;
use qimeng::util::bench::Bench;

fn main() {
    let t1 = Bench::new("table1_full_grid").samples(20).run(tables::table1);
    println!(
        "table1 mean {:?} — 1 s target: {}",
        t1.mean,
        if t1.mean < std::time::Duration::from_secs(1) { "MET" } else { "MISSED" }
    );
    Bench::new("table2_mla").samples(50).run(tables::table2);
    Bench::new("table3_llm_ablation").samples(50).run(tables::table3);
    Bench::new("table5_prompt_ablation").samples(50).run(tables::table5);
    Bench::new("table6_fp8").samples(50).run(tables::table6);
    Bench::new("table7_t4_grid").samples(20).run(tables::table7);
    Bench::new("table8_real_models").samples(20).run(tables::table8);
    Bench::new("table9_nsa").samples(50).run(tables::table9);
    Bench::new("figure1").samples(50).run(tables::figure1);
}
