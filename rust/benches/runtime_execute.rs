//! PJRT runtime benches: artifact compile time and steady-state execute
//! latency/throughput for the serving shapes. Requires `make artifacts`.
//! Reported TFLOPS here are CPU-interpret numbers — structural only; the
//! GPU estimates come from the perf model (DESIGN.md §2).

use std::path::PathBuf;

use qimeng::runtime::registry::{AttnSignature, Registry};
use qimeng::util::bench::{fmt_rate, Bench};
use qimeng::util::prng::Rng;

fn main() {
    let dir = PathBuf::from("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping runtime benches: run `make artifacts` first");
        return;
    }
    let reg = Registry::open(&dir).expect("open registry");

    // Compile-time bench on a fresh registry each iteration.
    let first_id = reg.attention_metas().next().unwrap().id.clone();
    Bench::new("artifact_compile_cold").samples(5).warmup(0).run(|| {
        let fresh = Registry::open(&dir).unwrap();
        fresh.executable(&first_id).unwrap()
    });

    // Steady-state execution for a representative artifact per variant.
    for meta in reg.attention_metas() {
        let sig = AttnSignature::from_meta(meta).unwrap();
        if sig.batch != 1 || !sig.causal {
            continue;
        }
        let exe = reg.executable(&meta.id).unwrap();
        let mut rng = Rng::new(7);
        let mut gen = |n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.normal() as f32 * 0.5).collect()
        };
        let q = gen(sig.batch * sig.q_heads * sig.seq * sig.qk_dim);
        let k = gen(sig.batch * sig.kv_heads * sig.kv * sig.qk_dim);
        let v = gen(sig.batch * sig.kv_heads * sig.kv * sig.v_dim);
        let qs = [sig.batch as i64, sig.q_heads as i64, sig.seq as i64, sig.qk_dim as i64];
        let ks = [sig.batch as i64, sig.kv_heads as i64, sig.kv as i64, sig.qk_dim as i64];
        let vs = [sig.batch as i64, sig.kv_heads as i64, sig.kv as i64, sig.v_dim as i64];
        let report = Bench::new(format!("execute_{}", meta.id)).samples(10).run(|| {
            reg.runtime
                .execute_f32(&exe, &[(&q, &qs), (&k, &ks), (&v, &vs)])
                .unwrap()
        });
        // Effective attention FLOPs through the CPU backend.
        let flops = 2.0
            * (sig.batch * sig.q_heads * sig.seq * sig.kv * (sig.qk_dim + sig.v_dim)) as f64
            * if sig.causal { 0.5 } else { 1.0 };
        println!(
            "  -> {} attention-flops/s (CPU interpret path)",
            fmt_rate(flops / report.mean.as_secs_f64())
        );
    }
}
