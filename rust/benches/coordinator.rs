//! Coordinator benches: serving throughput/latency under open-loop load
//! across executor-shard counts, batching on vs off (window = 0), plus
//! the pure batcher-planning hot path. §Perf target: coordinator
//! overhead ≤ 5% of kernel execute time at batch 8.
//!
//! Modes:
//!   cargo bench --bench coordinator              full run
//!   cargo bench --bench coordinator -- --smoke   tiny request counts
//!       (CI smoke: fails on any serve error or a planning-time
//!       regression, and records results to BENCH_serve.json)
//!
//! Serving sections use the PJRT executor when `artifacts/manifest.txt`
//! exists, and fall back to the in-process reference executor otherwise
//! (so the scheduler path is exercised on machines without `make
//! artifacts` — including CI).

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use qimeng::autotune::cache::TuneCache;
use qimeng::coordinator::batcher::plan_batches;
use qimeng::coordinator::scheduler::{ArtifactInfo, ReferenceExecutor, ServeTopology};
use qimeng::coordinator::{
    run_stream, BatchKv, Coordinator, Executor, ExecutorSpec, FamilyKey, ServeConfig,
    ServeReport,
};
use qimeng::sketch::spec::{AttnVariant, KvLayout};
use qimeng::util::bench::Bench;
use qimeng::workload::{request_stream_mixed, shared_prefix_stream};

fn start(shards: usize, window_ms: u64, executor: ExecutorSpec) -> Coordinator {
    Coordinator::start(ServeConfig {
        artifacts_dir: "artifacts".into(),
        batch_window: Duration::from_millis(window_ms),
        shards,
        executor,
        ..ServeConfig::default()
    })
    .expect("coordinator start")
}

fn serve(shards: usize, window_ms: u64, executor: ExecutorSpec, n: usize) -> ServeReport {
    let coordinator = start(shards, window_ms, executor.clone());
    // Warm every family once (compiles executables / primes caches).
    let warm =
        request_stream_mixed(&coordinator.families, coordinator.families.len() * 2, 1e6, 0.5, 3);
    let _ = run_stream(&coordinator, &warm, 1e9);
    let stream = request_stream_mixed(&coordinator.families, n, 1e6, 0.5, 11);
    let report = run_stream(&coordinator, &stream, 1e9);
    coordinator.shutdown();
    report
}

/// Shared-prefix serving: one pass over a fanout-heavy decode stream
/// with pregenerated payloads, either over COW-shared prefix pages or
/// private per-request KV copies. Returns (admitted QPS, KV bytes
/// charged per request, outputs in submission order).
fn serve_shared_prefix(
    payloads: &[(FamilyKey, Vec<f32>, Vec<f32>, Vec<f32>)],
    prefix_cache: bool,
    kv_budget_bytes: usize,
) -> (f64, f64, Vec<Vec<f32>>) {
    let mut fams: Vec<FamilyKey> = Vec::new();
    for (fam, ..) in payloads {
        if !fams.contains(fam) {
            fams.push(fam.clone());
        }
    }
    let topo = ServeTopology::synthetic(&fams, &[1, 2, 4, 8]);
    let config = ServeConfig {
        artifacts_dir: "unused".into(),
        batch_window: Duration::from_millis(2),
        shards: 4,
        executor: ExecutorSpec::Reference,
        kv_budget_bytes,
        prefix_cache,
        ..ServeConfig::default()
    };
    let coordinator = Coordinator::start_with_topology(config, topo, TuneCache::new(), false)
        .expect("coordinator start");
    let owned: Vec<_> = payloads.to_vec();
    let t0 = Instant::now();
    let rxs: Vec<_> = owned
        .into_iter()
        .map(|(fam, q, k, v)| coordinator.submit(fam, q, k, v))
        .collect();
    let outs: Vec<Vec<f32>> = rxs
        .into_iter()
        .map(|rx| rx.recv().expect("reply").outcome.into_result().expect("serve error"))
        .collect();
    let wall = t0.elapsed();
    let charged = coordinator.metrics.kv_charged_bytes.load(Ordering::Relaxed);
    coordinator.shutdown();
    let n = payloads.len() as f64;
    (n / wall.as_secs_f64(), charged as f64 / n, outs)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut failures: Vec<String> = Vec::new();

    // -- pure planning hot path (no execution) --
    let fam = FamilyKey {
        variant: AttnVariant::Mha,
        causal: true,
        qk_dim: 64,
        v_dim: 64,
        q_heads: 4,
        kv_heads: 4,
        seq: 256,
        kv: 256,
        kv_layout: KvLayout::Contiguous,
        direction: qimeng::sketch::spec::Direction::Forward,
        pattern: qimeng::sketch::spec::ScorePattern::Dense,
    };
    let caps: BTreeMap<FamilyKey, Vec<usize>> = [(fam.clone(), vec![1, 4])].into();
    let pending: Vec<(usize, FamilyKey, bool)> =
        (0..256).map(|i| (i, fam.clone(), i % 7 == 0)).collect();
    let samples = if smoke { 40 } else { 200 };
    let rep = Bench::new("batch_planning_256_pending")
        .samples(samples)
        .run(|| plan_batches(&pending, &caps));
    println!("  -> {:.1} plans/ms", 1e-3 / (rep.mean.as_secs_f64() / 64.0));
    // Scheduler-overhead guard: planning 256 pending requests must stay
    // far below any real execute time (ms-scale); 5 ms is a regression.
    let planning_us = rep.mean.as_secs_f64() * 1e6;
    if planning_us > 5_000.0 {
        failures.push(format!("batch planning took {planning_us:.0} us for 256 pending"));
    }

    // -- end-to-end serving across shard counts --
    let executor = if std::path::Path::new("artifacts/manifest.txt").exists() {
        ExecutorSpec::Pjrt
    } else {
        eprintln!("artifacts/manifest.txt absent: serving via the reference executor");
        ExecutorSpec::Reference
    };
    let n = if smoke { 24 } else { 96 };
    let mut results: Vec<(String, f64, usize)> = Vec::new();
    for shards in [1usize, 4] {
        let report = serve(shards, 5, executor.clone(), n);
        println!(
            "serve_shards{shards}: {} ok in {:.2?} -> {:.1} req/s, occupancy {:.2}, \
             p50 {:.1?}, p95 {:.1?}",
            report.ok,
            report.wall,
            report.throughput_rps,
            report.mean_occupancy,
            report.p50,
            report.p95
        );
        if report.errors > 0 {
            failures.push(format!("{} serve errors at --shards {shards}", report.errors));
        }
        results.push((format!("shards{shards}"), report.throughput_rps, report.ok));
    }
    let speedup = if results.len() == 2 && results[0].1 > 0.0 {
        results[1].1 / results[0].1
    } else {
        0.0
    };
    println!("shards4 / shards1 throughput = {speedup:.2}x");

    // Batched vs unbatched (window 0) at 1 shard.
    for (label, window_ms) in [("batched_w5ms", 5u64), ("unbatched_w0", 0)] {
        let report = serve(1, window_ms, executor.clone(), n);
        println!(
            "serve_{label}: {} ok -> {:.1} req/s, occupancy {:.2}",
            report.ok, report.throughput_rps, report.mean_occupancy
        );
        if report.errors > 0 {
            failures.push(format!("{} serve errors in {label}", report.errors));
        }
    }

    // -- continuous batching + COW shared-prefix KV caching --
    // A fanout-heavy decode stream (many requests per shared prefix)
    // under a KV budget sized so the *shared* resident set (one page run
    // per prefix) fits with headroom, while private per-request copies
    // must cycle through the pool — the regime prefix caching targets.
    let (n_prefixes, fanout) = (6usize, 8usize);
    let stream = shared_prefix_stream(n_prefixes, fanout, 23);
    let payloads: Vec<(FamilyKey, Vec<f32>, Vec<f32>, Vec<f32>)> = stream
        .iter()
        .map(|r| {
            let (q, k, v) = r.payload();
            (r.family.clone(), q, k, v)
        })
        .collect();
    let group_bytes: usize = {
        let mut seen: Vec<&FamilyKey> = Vec::new();
        let mut total = 0usize;
        for (fam, ..) in &payloads {
            if !seen.contains(&fam) {
                seen.push(fam);
                total += fam.kv_bytes();
            }
        }
        total
    };
    let budget = group_bytes + group_bytes / 8;
    let (qps_shared, bpr_shared, out_shared) = serve_shared_prefix(&payloads, true, budget);
    let (qps_private, bpr_private, out_private) =
        serve_shared_prefix(&payloads, false, budget);
    println!(
        "shared_prefix fanout{fanout}: {qps_shared:.0} req/s @ {:.0} KiB/req (COW) vs \
         {qps_private:.0} req/s @ {:.0} KiB/req (private)",
        bpr_shared / 1024.0,
        bpr_private / 1024.0
    );
    let qps_ratio = if qps_private > 0.0 { qps_shared / qps_private } else { 0.0 };
    let bytes_ratio = if bpr_private > 0.0 { bpr_shared / bpr_private } else { 1.0 };
    println!(
        "shared_prefix: {qps_ratio:.2}x admitted QPS, {bytes_ratio:.3}x KV bytes/request"
    );
    if qps_ratio < 1.5 {
        failures.push(format!(
            "shared-prefix QPS {qps_ratio:.2}x < 1.5x private baseline at fanout {fanout}"
        ));
    }
    if bytes_ratio > 0.5 {
        failures.push(format!(
            "shared-prefix KV bytes {bytes_ratio:.3}x > 0.5x private baseline"
        ));
    }
    // Bit-exactness: COW-shared, private-copy, and a solo dense oracle
    // must all agree exactly — sharing pages is a residency optimization,
    // never a numerics change.
    let info = ArtifactInfo { id: "oracle".to_string(), cand: None, obs_key: String::new() };
    for (i, (fam, q, k, v)) in payloads.iter().enumerate() {
        let want = ReferenceExecutor::default()
            .execute_batch(fam, &info, 1, q, BatchKv::Dense { k, v })
            .expect("oracle");
        if out_shared[i] != want || out_private[i] != want {
            failures
                .push(format!("shared-prefix request {i} diverged from the dense oracle"));
            break;
        }
    }

    // Record results where CI can diff them.
    let json = format!(
        "{{\n  \"mode\": \"{}\",\n  \"executor\": \"{}\",\n  \"requests\": {n},\n  \
         \"planning_us_256_pending\": {planning_us:.1},\n  \
         \"shards1_rps\": {:.2},\n  \"shards4_rps\": {:.2},\n  \"speedup\": {speedup:.3},\n  \
         \"shared_prefix_n_prefixes\": {n_prefixes},\n  \
         \"shared_prefix_fanout\": {fanout},\n  \
         \"shared_prefix_qps\": {qps_shared:.1},\n  \
         \"shared_prefix_kv_bytes_per_request\": {bpr_shared:.0},\n  \
         \"shared_prefix_qps_ratio\": {qps_ratio:.3},\n  \
         \"shared_prefix_kv_bytes_ratio\": {bytes_ratio:.3}\n}}\n",
        if smoke { "smoke" } else { "full" },
        match executor {
            ExecutorSpec::Pjrt => "pjrt",
            _ => "reference",
        },
        results.first().map(|r| r.1).unwrap_or(0.0),
        results.get(1).map(|r| r.1).unwrap_or(0.0),
    );
    if let Err(e) = std::fs::write("BENCH_serve.json", &json) {
        eprintln!("warning: could not write BENCH_serve.json: {e}");
    } else {
        println!("recorded BENCH_serve.json:\n{json}");
    }

    if !failures.is_empty() {
        eprintln!("coordinator bench FAILED:");
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
}
