//! Coordinator benches: serving throughput/latency under open-loop load,
//! batching on vs off (window = 0), plus the pure batcher-planning hot
//! path. §Perf target: coordinator overhead ≤ 5% of kernel execute time
//! at batch 8. Requires `make artifacts`.

use std::collections::BTreeMap;
use std::time::Duration;

use qimeng::coordinator::batcher::plan_batches;
use qimeng::coordinator::{run_stream, Coordinator, FamilyKey, ServeConfig};
use qimeng::sketch::spec::AttnVariant;
use qimeng::util::bench::Bench;
use qimeng::workload::request_stream;

fn main() {
    // -- pure planning hot path (no PJRT) --
    let fam = FamilyKey {
        variant: AttnVariant::Mha,
        causal: true,
        qk_dim: 64,
        v_dim: 64,
        q_heads: 4,
        kv_heads: 4,
        seq: 256,
        kv: 256,
    };
    let caps: BTreeMap<FamilyKey, Vec<usize>> = [(fam.clone(), vec![1, 4])].into();
    let pending: Vec<(usize, FamilyKey, bool)> =
        (0..256).map(|i| (i, fam.clone(), i % 7 == 0)).collect();
    let rep = Bench::new("batch_planning_256_pending").samples(200).run(|| {
        plan_batches(&pending, &caps)
    });
    println!("  -> {:.1} plans/ms", 1e-3 / (rep.mean.as_secs_f64() / 64.0));

    // -- end-to-end serving --
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping serving benches: run `make artifacts` first");
        return;
    }
    for (label, window_ms) in [("batched_w5ms", 5u64), ("unbatched_w0", 0)] {
        let coordinator = Coordinator::start(ServeConfig {
            artifacts_dir: "artifacts".into(),
            batch_window: Duration::from_millis(window_ms),
        })
        .expect("coordinator");
        // Warm all executables once.
        let warm = request_stream(&coordinator.families, coordinator.families.len() * 4, 1e6, 3);
        let _ = run_stream(&coordinator, &warm, 1e9);
        let stream = request_stream(&coordinator.families, 64, 1e6, 11);
        let t0 = std::time::Instant::now();
        let report = run_stream(&coordinator, &stream, 1e9);
        println!(
            "serve_{label}: {} ok in {:.2?} -> {:.1} req/s, occupancy {:.2}, p50 {:.1?}, p95 {:.1?}",
            report.ok,
            t0.elapsed(),
            report.throughput_rps,
            report.mean_occupancy,
            report.p50,
            report.p95
        );
        coordinator.shutdown();
    }
}
