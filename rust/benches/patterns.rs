//! Score-pattern benches: the same attention problem executed through
//! the compiled engine under the dense, block-sparse (selection-table
//! gather) and window+global patterns, single-thread and parallel.
//! §Perf tracks the selection win (block-sparse O(n·k) vs the dense
//! O(n²) sweep at long kv) and the window+global mask overhead.
//!
//! Modes:
//!   cargo bench --bench patterns              full run
//!   cargo bench --bench patterns -- --smoke   fewer samples (CI):
//!       gates on 1-vs-N-thread bit-identity for every pattern and on
//!       the block-sparse scaling law (a fixed selection budget must
//!       beat the dense sweep at kv >= 4k), records BENCH_patterns.json.

use std::collections::BTreeMap;

use qimeng::perfmodel::gpu::GpuArch;
use qimeng::reasoner::generate_tl_code;
use qimeng::reasoner::profiles::LlmProfile;
use qimeng::sketch::spec::{AttnVariant, OpSpec, ScorePattern};
use qimeng::util::bench::Bench;
use qimeng::util::prng::Rng;
use qimeng::verify::exec::{default_threads, run_attention_tables, run_attention_threads};
use qimeng::verify::tensor::Tensor2;

struct SelectionRow {
    label: &'static str,
    kv: usize,
    dense_us: f64,
    sparse_us: f64,
    dense_nt_us: f64,
    sparse_nt_us: f64,
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let samples = if smoke { 3 } else { 12 };
    let threads = default_threads().max(2);
    let arch = GpuArch::a100();
    let profile = LlmProfile::deepseek_v3();
    let scale = 1.0 / 8.0;
    let mut failures: Vec<String> = Vec::new();

    // ---- Selection scaling: cross-attention decode shape (128 queries)
    // with a fixed 1024-key selection budget against a growing kv. The
    // dense sweep is O(seq * kv); the selection loop is O(seq * topk *
    // block) — flat in kv — so the speedup must widen with kv.
    const SEQ: usize = 128;
    let mut sel_rows: Vec<SelectionRow> = Vec::new();
    for (label, kv) in [("sel_kv4096", 4096usize), ("sel_kv8192", 8192usize)] {
        let mut base = OpSpec::benchmark(AttnVariant::Mha, SEQ, 64, false);
        base.batch = 1;
        let dense_spec = base.with_kv_len(kv).unwrap();
        let sparse_spec = dense_spec
            .with_pattern(ScorePattern::BlockSparse { block: 64, topk: 16 })
            .unwrap();
        let dense = generate_tl_code(&dense_spec, &arch, &profile).program;
        let sparse = generate_tl_code(&sparse_spec, &arch, &profile).program;
        let params = sparse.params();
        let bn = params["BN"] as usize;
        let topk_tiles = params["sel_topk"] as usize;

        let q = Tensor2::randn(SEQ, 64, 1);
        let k = Tensor2::randn(kv, 64, 2);
        let v = Tensor2::randn(kv, 64, 3);

        // A seeded shuffled selection of topk_tiles distinct kv tiles.
        let total = kv / bn;
        let mut sel: Vec<i64> = (0..total as i64).collect();
        let mut rng = Rng::new(0xBEEF ^ kv as u64);
        for i in (1..total).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            sel.swap(i, j);
        }
        sel.truncate(topk_tiles);
        let mut tables = BTreeMap::new();
        tables.insert("sel_table".to_string(), sel);
        let empty = BTreeMap::new();

        // Bit-identity gate before timing anything: every pattern must
        // produce the same bits at 1 and N threads.
        for (name, program, tb) in [("dense", &dense, &empty), ("sparse", &sparse, &tables)] {
            let one = run_attention_tables(program, &q, &k, &v, scale, tb, 1).unwrap();
            let many = run_attention_tables(program, &q, &k, &v, scale, tb, threads).unwrap();
            if one.data != many.data {
                failures.push(format!("{label}: {name} 1t != {threads}t"));
            }
        }

        let d1 = Bench::new(format!("pattern_dense_1t_{label}"))
            .warmup(1)
            .samples(samples)
            .run(|| run_attention_threads(&dense, &q, &k, &v, scale, 1).unwrap());
        let s1 = Bench::new(format!("pattern_sparse_1t_{label}"))
            .warmup(1)
            .samples(samples)
            .run(|| run_attention_tables(&sparse, &q, &k, &v, scale, &tables, 1).unwrap());
        let dn = Bench::new(format!("pattern_dense_{threads}t_{label}"))
            .warmup(1)
            .samples(samples)
            .run(|| run_attention_threads(&dense, &q, &k, &v, scale, threads).unwrap());
        let sn = Bench::new(format!("pattern_sparse_{threads}t_{label}"))
            .warmup(1)
            .samples(samples)
            .run(|| {
                run_attention_tables(&sparse, &q, &k, &v, scale, &tables, threads).unwrap()
            });

        let row = SelectionRow {
            label,
            kv,
            dense_us: d1.mean.as_secs_f64() * 1e6,
            sparse_us: s1.mean.as_secs_f64() * 1e6,
            dense_nt_us: dn.mean.as_secs_f64() * 1e6,
            sparse_nt_us: sn.mean.as_secs_f64() * 1e6,
        };
        println!(
            "  -> {label}: sparse speedup 1t = {:.2}x, {threads}t = {:.2}x \
             ({topk_tiles}/{total} tiles attended)",
            row.dense_us / row.sparse_us,
            row.dense_nt_us / row.sparse_nt_us,
        );
        sel_rows.push(row);
    }

    // ---- Window+global: mask-refinement pattern on a causal square
    // sweep. The host engines stream every tile and mask in-register, so
    // this tracks pure mask overhead (~1x), not a tile-skip win.
    let wg_label = "wg_seq1024_win256_g64";
    let dense_causal_spec = {
        let mut s = OpSpec::benchmark(AttnVariant::Mha, 1024, 64, true);
        s.batch = 1;
        s
    };
    let wg_spec = dense_causal_spec
        .with_pattern(ScorePattern::WindowGlobal { window: 256, n_global: 64 })
        .unwrap();
    let dense_causal = generate_tl_code(&dense_causal_spec, &arch, &profile).program;
    let wg = generate_tl_code(&wg_spec, &arch, &profile).program;
    let q = Tensor2::randn(1024, 64, 4);
    let k = Tensor2::randn(1024, 64, 5);
    let v = Tensor2::randn(1024, 64, 6);
    {
        let one = run_attention_threads(&wg, &q, &k, &v, scale, 1).unwrap();
        let many = run_attention_threads(&wg, &q, &k, &v, scale, threads).unwrap();
        if one.data != many.data {
            failures.push(format!("{wg_label}: 1t != {threads}t"));
        }
    }
    let c1 = Bench::new(format!("pattern_causal_1t_{wg_label}"))
        .warmup(1)
        .samples(samples)
        .run(|| run_attention_threads(&dense_causal, &q, &k, &v, scale, 1).unwrap());
    let w1 = Bench::new(format!("pattern_wg_1t_{wg_label}"))
        .warmup(1)
        .samples(samples)
        .run(|| run_attention_threads(&wg, &q, &k, &v, scale, 1).unwrap());
    let wn = Bench::new(format!("pattern_wg_{threads}t_{wg_label}"))
        .warmup(1)
        .samples(samples)
        .run(|| run_attention_threads(&wg, &q, &k, &v, scale, threads).unwrap());
    let (causal_us, wg_us, wg_nt_us) = (
        c1.mean.as_secs_f64() * 1e6,
        w1.mean.as_secs_f64() * 1e6,
        wn.mean.as_secs_f64() * 1e6,
    );
    println!(
        "  -> {wg_label}: mask overhead = {:.2}x, 1t/{threads}t = {:.2}x",
        wg_us / causal_us,
        wg_us / wg_nt_us,
    );

    let mut json = format!(
        "{{\n  \"mode\": \"{}\",\n  \"threads\": {threads},\n  \"selection\": [\n",
        if smoke { "smoke" } else { "full" }
    );
    for (i, r) in sel_rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"label\": \"{}\", \"kv\": {}, \"dense_us\": {:.1}, \"sparse_us\": {:.1}, \
             \"dense_nt_us\": {:.1}, \"sparse_nt_us\": {:.1}, \"sparse_speedup\": {:.2}}}{}\n",
            r.label,
            r.kv,
            r.dense_us,
            r.sparse_us,
            r.dense_nt_us,
            r.sparse_nt_us,
            r.dense_us / r.sparse_us,
            if i + 1 < sel_rows.len() { "," } else { "" },
        ));
    }
    let min_speedup = sel_rows
        .iter()
        .map(|r| r.dense_us / r.sparse_us)
        .fold(f64::INFINITY, f64::min);
    json.push_str(&format!(
        "  ],\n  \"window_global\": {{\"label\": \"{wg_label}\", \"causal_us\": {causal_us:.1}, \
         \"wg_us\": {wg_us:.1}, \"wg_nt_us\": {wg_nt_us:.1}, \"mask_overhead\": {:.3}}},\n  \
         \"min_sparse_speedup\": {min_speedup:.2}\n}}\n",
        wg_us / causal_us,
    ));
    if let Err(e) = std::fs::write("BENCH_patterns.json", &json) {
        eprintln!("warning: could not write BENCH_patterns.json: {e}");
    } else {
        println!("recorded BENCH_patterns.json:\n{json}");
    }

    // Regressions: bit divergence always fails; in CI (smoke mode) the
    // scaling law must hold too — a 16×64-key selection against kv >= 4k
    // streams at most 1/4 of the dense tiles, so even a noisy host run
    // must clear 2x. Full local runs report the speedup without gating.
    if smoke && min_speedup < 2.0 {
        failures.push(format!(
            "block-sparse selection only {min_speedup:.2}x faster than dense at kv >= 4k \
             (gate 2.0x — O(n·k) scaling is broken)"
        ));
    }
    if !failures.is_empty() {
        eprintln!("patterns bench FAILED:");
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
}
