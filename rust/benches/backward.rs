//! Backward-pass benches: the forward kernel vs the three-gradient
//! backward bundle through the compiled engine, single-thread and
//! parallel. §Perf tracks the backward/forward wall-clock ratio (the
//! FlashAttention-2 accounting predicts ~2.5x from the 5-vs-2 GEMM
//! count) and the parallel-sweep speedup of the KV-block-parallel dK/dV
//! programs.
//!
//! Modes:
//!   cargo bench --bench backward              full run
//!   cargo bench --bench backward -- --smoke   fewer samples (CI):
//!       gates on the gradient check (compiled engine vs the analytic
//!       oracle within BACKWARD_NUMERIC_TOL) before timing anything,
//!       records BENCH_backward.json.

use std::collections::BTreeMap;

use qimeng::reasoner::generate_tl_code;
use qimeng::reasoner::profiles::LlmProfile;
use qimeng::perfmodel::gpu::GpuArch;
use qimeng::sketch::spec::{AttnVariant, Direction, OpSpec};
use qimeng::sketch::{backward_sketches, GradTarget};
use qimeng::tl::ast::TlProgram;
use qimeng::util::bench::Bench;
use qimeng::verify::exec::{default_threads, run_attention_threads, run_program_tables};
use qimeng::verify::tensor::{reference_attention_grads, Tensor2};
use qimeng::verify::BACKWARD_NUMERIC_TOL;

struct Row {
    label: &'static str,
    forward_us: f64,
    backward_us: f64,
    forward_nt_us: f64,
    backward_nt_us: f64,
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let samples = if smoke { 5 } else { 20 };
    let threads = default_threads().max(2);
    let arch = GpuArch::a100();
    let profile = LlmProfile::deepseek_v3();
    let mut failures: Vec<String> = Vec::new();
    let mut rows: Vec<Row> = Vec::new();

    for (label, seq) in [("sweep_128", 128usize), ("sweep_256", 256usize)] {
        let mut fwd_spec = OpSpec::benchmark(AttnVariant::Mha, seq, 64, true);
        fwd_spec.batch = 1;
        let bwd_spec = fwd_spec.with_direction(Direction::Backward);

        let forward = generate_tl_code(&fwd_spec, &arch, &profile).program;
        let backward: Vec<(GradTarget, TlProgram)> = backward_sketches(&bwd_spec)
            .into_iter()
            .map(|(g, sk)| {
                (g, qimeng::reasoner::reason(&sk, &bwd_spec, &arch, &profile).program)
            })
            .collect();

        let q = Tensor2::randn(seq, 64, 1);
        let k = Tensor2::randn(seq, 64, 2);
        let v = Tensor2::randn(seq, 64, 3);
        let dout = Tensor2::randn(seq, 64, 4);
        let scale = 1.0 / 8.0;
        let grads = reference_attention_grads(&q, &k, &v, &dout, scale, true, None);
        let mut named: BTreeMap<&str, &Tensor2> = BTreeMap::new();
        named.insert("Q", &q);
        named.insert("K", &k);
        named.insert("V", &v);
        named.insert("dO", &dout);
        named.insert("Lse", &grads.lse);
        named.insert("Delta", &grads.delta);
        let tables = BTreeMap::new();

        // Gradient-check gate before timing anything.
        for (grad, program) in &backward {
            let got = run_program_tables(program, &named, scale, &tables, 1)
                .unwrap_or_else(|e| panic!("{label}/{grad}: {e}"));
            let want = match grad {
                GradTarget::DQ => &grads.dq,
                GradTarget::DK => &grads.dk,
                GradTarget::DV => &grads.dv,
            };
            let diff = got.max_abs_diff(want);
            if diff >= BACKWARD_NUMERIC_TOL {
                failures.push(format!("{label}: {grad} gradient check failed ({diff})"));
            }
        }

        let run_backward = |t: usize| {
            for (_, program) in &backward {
                run_program_tables(program, &named, scale, &tables, t).unwrap();
            }
        };

        let f1 = Bench::new(format!("fwd_1t_{label}"))
            .warmup(1)
            .samples(samples)
            .run(|| run_attention_threads(&forward, &q, &k, &v, scale, 1).unwrap());
        let b1 = Bench::new(format!("bwd_1t_{label}"))
            .warmup(1)
            .samples(samples)
            .run(|| run_backward(1));
        let fn_ = Bench::new(format!("fwd_{threads}t_{label}"))
            .warmup(1)
            .samples(samples)
            .run(|| run_attention_threads(&forward, &q, &k, &v, scale, threads).unwrap());
        let bn = Bench::new(format!("bwd_{threads}t_{label}"))
            .warmup(1)
            .samples(samples)
            .run(|| run_backward(threads));

        let row = Row {
            label,
            forward_us: f1.mean.as_secs_f64() * 1e6,
            backward_us: b1.mean.as_secs_f64() * 1e6,
            forward_nt_us: fn_.mean.as_secs_f64() * 1e6,
            backward_nt_us: bn.mean.as_secs_f64() * 1e6,
        };
        println!(
            "  -> {label}: backward/forward = {:.2}x (1t), backward 1t/{threads}t = {:.2}x",
            row.backward_us / row.forward_us,
            row.backward_us / row.backward_nt_us,
        );
        rows.push(row);
    }

    let mut json = format!(
        "{{\n  \"mode\": \"{}\",\n  \"threads\": {threads},\n  \"sweeps\": [\n",
        if smoke { "smoke" } else { "full" }
    );
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"label\": \"{}\", \"forward_us\": {:.1}, \"backward_us\": {:.1}, \
             \"forward_nt_us\": {:.1}, \"backward_nt_us\": {:.1}, \
             \"bwd_over_fwd\": {:.2}, \"bwd_parallel_speedup\": {:.2}}}{}\n",
            r.label,
            r.forward_us,
            r.backward_us,
            r.forward_nt_us,
            r.backward_nt_us,
            r.backward_us / r.forward_us,
            r.backward_us / r.backward_nt_us,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    json.push_str("  ]\n}\n");
    if let Err(e) = std::fs::write("BENCH_backward.json", &json) {
        eprintln!("warning: could not write BENCH_backward.json: {e}");
    } else {
        println!("recorded BENCH_backward.json:\n{json}");
    }

    if !failures.is_empty() {
        eprintln!("backward bench FAILED:");
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
}
