//! Fault-injection bench: goodput under injected executor errors, shard
//! crash-recovery time, degraded-lane share when every compiled variant
//! is quarantined, and deadline shedding under latency spikes — the
//! numbers DESIGN.md §13 gates on.
//!
//! Modes:
//!   cargo bench --bench faults              full run
//!   cargo bench --bench faults -- --smoke   tiny request counts
//!       (CI smoke: fails when goodput at a 10% injected error rate
//!       drops below 90%, recovery from a shard kill exceeds 5 s, or a
//!       degraded reply diverges from the reference oracle; records
//!       results to BENCH_faults.json)

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use qimeng::autotune::cache::TuneCache;
use qimeng::coordinator::scheduler::{ArtifactInfo, ReferenceExecutor, ServeTopology};
use qimeng::coordinator::{
    run_stream, BatchKv, Coordinator, Executor, ExecutorSpec, FaultPlan, RequestOutcome,
    RetryPolicy, ServeConfig, SupervisorConfig,
};
use qimeng::workload::{fault_stream, SyntheticRequest};

fn fast_supervisor() -> SupervisorConfig {
    SupervisorConfig {
        heartbeat_timeout: Duration::from_millis(500),
        check_every: Duration::from_millis(1),
        max_restarts: 16,
    }
}

fn reference_config(shards: usize) -> ServeConfig {
    ServeConfig {
        artifacts_dir: "definitely-not-compiled-artifacts".into(),
        batch_window: Duration::from_millis(2),
        shards,
        executor: ExecutorSpec::Reference,
        supervisor: fast_supervisor(),
        ..ServeConfig::default()
    }
}

/// Goodput under a 10% injected executor error rate: bounded retry must
/// re-serve almost everything (p(fail) ≈ 0.1³ per request with 3
/// attempts). Returns (goodput, terminal-response conservation ok).
fn goodput_under_errors(n: usize) -> (f64, bool) {
    let config = ServeConfig {
        retry: RetryPolicy { max_attempts: 3, backoff: Duration::from_micros(200) },
        fault_plan: Some(FaultPlan { error_rate: 0.1, ..FaultPlan::default() }),
        ..reference_config(2)
    };
    let coordinator = Coordinator::start(config).expect("start");
    let stream = fault_stream(&coordinator.families, n, 1e6, 8.0, 0.5, 21);
    let report = run_stream(&coordinator, &stream, 1e9);
    let retries =
        coordinator.metrics.retries.load(Ordering::Relaxed);
    coordinator.shutdown();
    println!(
        "goodput_10pct_errors: {}/{} ok ({} errors, {} timeouts, {retries} retries)",
        report.ok, n, report.errors, report.timeouts
    );
    let conserved = report.ok + report.errors + report.timeouts == n;
    (report.ok as f64 / n as f64, conserved)
}

/// Executor that panics exactly once (the first batch on shard 0), then
/// behaves — a deterministic shard kill for measuring supervised
/// restart + re-serve latency.
struct PanicOnceExecutor {
    fired: Arc<AtomicBool>,
    shard: usize,
    inner: ReferenceExecutor,
}

impl Executor for PanicOnceExecutor {
    fn execute_batch(
        &mut self,
        family: &qimeng::coordinator::FamilyKey,
        info: &ArtifactInfo,
        capacity: usize,
        q: &[f32],
        kv: BatchKv<'_>,
    ) -> Result<Vec<f32>, String> {
        if self.shard == 0 && !self.fired.swap(true, Ordering::AcqRel) {
            panic!("bench: injected one-shot shard kill");
        }
        self.inner.execute_batch(family, info, capacity, q, kv)
    }

    fn kind(&self) -> &'static str {
        "panic-once"
    }
}

/// Kill one shard mid-stream and measure wall time until every request
/// (including the killed batch, re-queued by the supervisor) is served.
fn shard_kill_recovery(n: usize) -> (Duration, usize, u64) {
    let fired = Arc::new(AtomicBool::new(false));
    let factory_fired = fired.clone();
    let config = ServeConfig {
        executor: ExecutorSpec::Custom(Arc::new(move |shard| {
            Ok(Box::new(PanicOnceExecutor {
                fired: factory_fired.clone(),
                shard,
                inner: ReferenceExecutor::default(),
            }) as Box<dyn Executor>)
        })),
        retry: RetryPolicy { max_attempts: 4, backoff: Duration::from_micros(200) },
        ..reference_config(2)
    };
    let coordinator = Coordinator::start(config).expect("start");
    let fams = coordinator.families.clone();
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let req = SyntheticRequest {
                family: fams[i % fams.len()].clone(),
                seed: 4000 + i as u64,
                arrival: Duration::ZERO,
                prefix: None,
            };
            let (q, k, v) = req.payload();
            coordinator.submit(req.family.clone(), q, k, v)
        })
        .collect();
    let ok = rxs
        .into_iter()
        .filter(|rx| rx.recv().map(|r| r.outcome.is_ok()).unwrap_or(false))
        .count();
    let recovery = t0.elapsed();
    let restarts = coordinator.metrics.shard_restarts.load(Ordering::Relaxed);
    coordinator.shutdown();
    println!(
        "shard_kill_recovery: {ok}/{n} ok in {recovery:.2?} ({restarts} restart(s))"
    );
    (recovery, ok, restarts)
}

/// Executor that fails every compiled variant — drives the pool into
/// full quarantine so the degraded reference lane serves the traffic.
struct AlwaysFailingExecutor;

impl Executor for AlwaysFailingExecutor {
    fn execute_batch(
        &mut self,
        _family: &qimeng::coordinator::FamilyKey,
        info: &ArtifactInfo,
        _capacity: usize,
        _q: &[f32],
        _kv: BatchKv<'_>,
    ) -> Result<Vec<f32>, String> {
        Err(format!("bench: variant {} broken", info.id))
    }

    fn kind(&self) -> &'static str {
        "always-failing"
    }
}

/// Serve with every compiled variant failing: measure the share of
/// traffic the degraded lane absorbs and check one degraded reply
/// bit-exactly against a fresh reference run.
fn degraded_share(n: usize) -> (f64, bool) {
    let manifest = "artifact plain file=a.hlo.txt kind=attention variant=mha causal=0 \
         batch=1 q_heads=2 kv_heads=2 seq=1 kv=128 qk=64 vd=64 bm=64 bn=64 split_k=1\n\
         artifact splitk file=b.hlo.txt kind=attention variant=mha causal=0 \
         batch=1 q_heads=2 kv_heads=2 seq=1 kv=128 qk=64 vd=64 bm=64 bn=64 split_k=8\n";
    let metas = qimeng::runtime::registry::parse_manifest(manifest).unwrap();
    let topo = ServeTopology::from_manifest(&metas, &TuneCache::new(), usize::MAX).unwrap();
    let config = ServeConfig {
        artifacts_dir: "unused".into(),
        executor: ExecutorSpec::Custom(Arc::new(|_shard| {
            Ok(Box::new(AlwaysFailingExecutor) as Box<dyn Executor>)
        })),
        retry: RetryPolicy { max_attempts: 2, backoff: Duration::from_micros(100) },
        ..reference_config(1)
    };
    let coordinator =
        Coordinator::start_with_topology(config, topo, TuneCache::new(), false).expect("start");
    let fam = coordinator.families[0].clone();
    let mut degraded = 0usize;
    let mut bit_exact = true;
    for i in 0..n {
        let req = SyntheticRequest {
            family: fam.clone(),
            seed: 8000 + i as u64,
            arrival: Duration::ZERO,
            prefix: None,
        };
        let (q, k, v) = req.payload();
        let resp = coordinator
            .submit(fam.clone(), q.clone(), k.clone(), v.clone())
            .recv()
            .expect("reply");
        if resp.degraded {
            degraded += 1;
            if let RequestOutcome::Ok(out) = &resp.outcome {
                let info = ArtifactInfo {
                    id: "oracle".to_string(),
                    cand: None,
                    obs_key: String::new(),
                };
                let want = ReferenceExecutor::default()
                    .execute_batch(&fam, &info, 1, &q, BatchKv::Dense { k: &k, v: &v })
                    .expect("oracle");
                bit_exact &= out == &want;
            } else {
                bit_exact = false;
            }
        }
    }
    let quarantined = coordinator.quarantine.quarantined_count();
    coordinator.shutdown();
    println!(
        "degraded_share: {degraded}/{n} served degraded ({quarantined} variant(s) \
         quarantined, bit_exact={bit_exact})"
    );
    (degraded as f64 / n as f64, bit_exact)
}

/// Deadline shedding under injected latency spikes: every batch sleeps
/// past the request deadline, so queued work must shed with a distinct
/// Timeout outcome (never hang, never mislabel as an error).
fn deadline_shedding(n: usize) -> (usize, bool) {
    let config = ServeConfig {
        deadline: Some(Duration::from_millis(15)),
        fault_plan: Some(FaultPlan {
            spike_rate: 1.0,
            spike: Duration::from_millis(25),
            ..FaultPlan::default()
        }),
        ..reference_config(1)
    };
    let coordinator = Coordinator::start(config).expect("start");
    let stream = fault_stream(&coordinator.families, n, 1e6, 8.0, 0.5, 33);
    let report = run_stream(&coordinator, &stream, 1e9);
    coordinator.shutdown();
    println!(
        "deadline_shedding: {} ok, {} timeouts, {} errors of {n}",
        report.ok, report.timeouts, report.errors
    );
    let conserved = report.ok + report.errors + report.timeouts == n;
    (report.timeouts, conserved)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut failures: Vec<String> = Vec::new();

    let n = if smoke { 48 } else { 192 };
    let (goodput, conserved) = goodput_under_errors(n);
    if goodput < 0.9 {
        failures.push(format!("goodput {goodput:.3} < 0.90 at 10% injected errors"));
    }
    if !conserved {
        failures.push("goodput section lost terminal responses".to_string());
    }

    let kill_n = if smoke { 24 } else { 64 };
    let (recovery, ok, restarts) = shard_kill_recovery(kill_n);
    if recovery > Duration::from_millis(5000) {
        failures.push(format!("shard-kill recovery took {recovery:.2?} (> 5 s)"));
    }
    if ok < kill_n {
        failures.push(format!("{} requests lost to the shard kill", kill_n - ok));
    }
    if restarts == 0 {
        failures.push("shard kill did not register a supervised restart".to_string());
    }

    let deg_n = if smoke { 32 } else { 96 };
    let (share, bit_exact) = degraded_share(deg_n);
    if !bit_exact {
        failures.push("a degraded reply diverged from the reference oracle".to_string());
    }
    if share <= 0.0 {
        failures.push("pool never degraded despite every variant failing".to_string());
    }

    let dl_n = if smoke { 32 } else { 96 };
    let (timeouts, dl_conserved) = deadline_shedding(dl_n);
    if timeouts == 0 {
        failures.push("no Timeout outcomes despite certain deadline misses".to_string());
    }
    if !dl_conserved {
        failures.push("deadline section lost terminal responses".to_string());
    }

    let json = format!(
        "{{\n  \"mode\": \"{}\",\n  \"goodput_at_10pct_errors\": {goodput:.4},\n  \
         \"recovery_ms\": {:.1},\n  \"shard_restarts\": {restarts},\n  \
         \"degraded_share\": {share:.4},\n  \"degraded_bit_exact\": {bit_exact},\n  \
         \"timeouts\": {timeouts}\n}}\n",
        if smoke { "smoke" } else { "full" },
        recovery.as_secs_f64() * 1e3,
    );
    if let Err(e) = std::fs::write("BENCH_faults.json", &json) {
        eprintln!("warning: could not write BENCH_faults.json: {e}");
    } else {
        println!("recorded BENCH_faults.json:\n{json}");
    }

    if !failures.is_empty() {
        eprintln!("faults bench FAILED:");
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
}
