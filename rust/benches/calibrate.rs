//! Cost-model self-calibration bench: probe a small candidate grid on
//! the host engine, feed the observations through the calibration fit,
//! and record how much the fitted multipliers shrink the
//! observed-vs-modeled disagreement — plus whether calibration improves
//! (or at least preserves) the model's candidate *ranking* against the
//! measured ordering.
//!
//! Modes:
//!   cargo bench --bench calibrate              full run
//!   cargo bench --bench calibrate -- --smoke   same grid, CI-labelled run
//!       (the probe grid is already minimal: 3 shapes x 3 tiles x
//!       `measure::PROBE_SAMPLES` timed sweeps)
//!
//! Records BENCH_calib.json and exits non-zero if the fitted
//! calibration scores *worse* than the identity on its own fit set —
//! the identity floor in `calibrate::fit` makes that impossible unless
//! the fit/persistence plumbing regresses.
//!
//! Shape discipline: `measure::probe_wallclock` rewrites the probe's
//! `seq_len`/`kv_len` to `PROBE_BLOCKS * max(bm, bn)` and sweeps one
//! head, so every bench spec uses `seq = PROBE_BLOCKS * 64`, one head,
//! batch 1, and only candidates with `max(bm, bn) == 64` — the modeled
//! spec then matches the measured program exactly.

use qimeng::autotune::{cache, calibration_samples, measure, space};
use qimeng::perfmodel::calibrate::{self, Calibration};
use qimeng::perfmodel::gpu::GpuArch;
use qimeng::sketch::spec::{AttnVariant, OpSpec};

/// Probe tile cap: candidates keep `max(bm, bn) == TILE`, specs use
/// `seq = measure::PROBE_BLOCKS * TILE`.
const TILE: usize = 64;

fn bench_spec(head_dim: usize, causal: bool) -> OpSpec {
    let mut spec =
        OpSpec::benchmark(AttnVariant::Mha, measure::PROBE_BLOCKS * TILE, head_dim, causal);
    spec.batch = 1;
    spec.num_q_heads = 1;
    spec.num_kv_heads = 1;
    spec
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let arch = GpuArch::a100();
    let specs = [bench_spec(64, true), bench_spec(64, false), bench_spec(128, true)];
    let mut tune_cache = cache::TuneCache::new();
    let mut failures: Vec<String> = Vec::new();

    // Probe each shape's candidate slice and record the measured mean
    // as a serving-style observation (`TuneCache::observe`) — exactly
    // the entries `tlc tune --calibrate` fits against.
    let mut probed: Vec<(OpSpec, Vec<(space::Candidate, f64)>)> = Vec::new();
    for spec in &specs {
        let part = cache::spec_part(spec);
        // One candidate per (bm, bn) pair: the observed-cache key only
        // distinguishes bm/bn/split_k, so stage/warp variants of the
        // same tile would merge into one running-mean entry.
        let mut tiles = std::collections::BTreeSet::new();
        let cands: Vec<space::Candidate> = space::enumerate(spec, &arch)
            .into_iter()
            .filter(|c| {
                c.bm.max(c.bn) == TILE
                    && c.split_k == 1
                    && c.prefetch_pages == 1
                    && tiles.insert((c.bm, c.bn))
            })
            .collect();
        if cands.len() < 2 {
            failures.push(format!("{part}: fewer than 2 probe-sized candidates enumerated"));
            continue;
        }
        let mut rows = Vec::new();
        for (i, cand) in cands.iter().enumerate() {
            match measure::probe_wallclock(spec, &arch, cand, 7 + i as u64) {
                Ok(d) => {
                    let micros = d.as_secs_f64() * 1e6;
                    tune_cache.observe(&part, *cand, micros);
                    println!("  probed {part} {cand}: {micros:.1}us");
                    rows.push((*cand, micros));
                }
                Err(e) => failures.push(format!("{part} {cand}: probe failed: {e}")),
            }
        }
        probed.push((spec.clone(), rows));
    }

    // Fit on everything observed, exactly as `tlc tune --calibrate`.
    let (samples, unmatched) = calibration_samples(&tune_cache, &specs, &arch);
    if unmatched > 0 {
        failures.push(format!("{unmatched} observed shapes matched no bench spec"));
    }
    let identity = Calibration::identity();
    let pre = calibrate::disagreement(&samples, &identity);
    let fitted = calibrate::fit(&samples);
    let post = calibrate::disagreement(&samples, &fitted);
    println!("fit over {} samples: {fitted}", samples.len());
    println!(
        "disagreement (RMS log observed-vs-modeled): identity {pre:.4} -> calibrated {post:.4}"
    );

    // Rank agreement: does the model's best candidate (per shape) match
    // the measured-fastest one, before and after calibration? A global
    // scale correction cannot reorder candidates, so this only moves
    // when the three-term fit wins — but it must never *lose* ranks on
    // the grid it was fitted to without us noticing here.
    let mut agree_pre = 0usize;
    let mut agree_post = 0usize;
    for (spec, rows) in &probed {
        let fastest = rows
            .iter()
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .map(|(c, _)| *c)
            .expect("rows checked non-empty");
        let best_by = |cal: &Calibration| {
            rows.iter()
                .map(|(c, _)| (*c, space::model_seconds_calibrated(spec, &arch, c, cal)))
                .min_by(|a, b| a.1.total_cmp(&b.1))
                .map(|(c, _)| c)
                .expect("rows checked non-empty")
        };
        agree_pre += (best_by(&identity) == fastest) as usize;
        agree_post += (best_by(&fitted) == fastest) as usize;
    }
    println!(
        "rank agreement (model-best == measured-fastest): {agree_pre}/{} -> {agree_post}/{}",
        probed.len(),
        probed.len()
    );

    let json = format!(
        "{{\n  \"mode\": \"{}\",\n  \"shapes\": {},\n  \
         \"samples\": {},\n  \"unmatched_shapes\": {unmatched},\n  \
         \"pre_disagreement\": {pre:.4},\n  \"post_disagreement\": {post:.4},\n  \
         \"calibration\": {{\"gemm\": {:.6e}, \"softmax\": {:.6e}, \"membw\": {:.6e}}},\n  \
         \"rank_agree_pre\": {agree_pre},\n  \"rank_agree_post\": {agree_post}\n}}\n",
        if smoke { "smoke" } else { "full" },
        probed.len(),
        samples.len(),
        fitted.gemm,
        fitted.softmax,
        fitted.membw,
    );
    if let Err(e) = std::fs::write("BENCH_calib.json", &json) {
        eprintln!("warning: could not write BENCH_calib.json: {e}");
    } else {
        println!("recorded BENCH_calib.json:\n{json}");
    }

    // Hard gates: the fit set must be non-trivial, and the identity
    // floor guarantees calibration never scores worse than no
    // calibration on its own observations.
    if samples.is_empty() {
        failures.push("no fit samples assembled from the probed observations".into());
    }
    if post > pre + 1e-12 {
        failures.push(format!(
            "calibrated disagreement {post:.4} exceeds uncalibrated {pre:.4}"
        ));
    }
    // Rank agreement is recorded for the perf trajectory but not
    // hard-gated: the RMS-optimal fit may legitimately trade one rank
    // on a near-tie, and host-probe timing jitter decides near-ties.
    if !failures.is_empty() {
        eprintln!("calibrate bench FAILED:");
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
}
