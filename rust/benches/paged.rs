//! KV-layout benches: the same attention problem executed through the
//! compiled engine under the contiguous, paged (identity and shuffled
//! block tables) and sliding-window layouts, single-thread and parallel.
//! §Perf tracks the gather overhead (paged vs contiguous) and the
//! window win (sliding vs full causal sweep).
//!
//! Modes:
//!   cargo bench --bench paged              full run
//!   cargo bench --bench paged -- --smoke   fewer samples (CI):
//!       gates on paged(identity) == contiguous bit-identity, fails on
//!       pathological gather slowdowns, records BENCH_paged.json.

use std::collections::BTreeMap;

use qimeng::reasoner::generate_tl_code;
use qimeng::reasoner::profiles::LlmProfile;
use qimeng::perfmodel::gpu::GpuArch;
use qimeng::sketch::spec::{AttnVariant, KvLayout, OpSpec};
use qimeng::util::bench::Bench;
use qimeng::verify::exec::{default_threads, run_attention_tables, run_attention_threads};
use qimeng::verify::tensor::Tensor2;
use qimeng::verify::{identity_table, paged_shuffle};

struct Row {
    label: &'static str,
    contiguous_us: f64,
    paged_us: f64,
    sliding_us: f64,
    contiguous_nt_us: f64,
    paged_nt_us: f64,
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let samples = if smoke { 5 } else { 20 };
    let threads = default_threads().max(2);
    let arch = GpuArch::a100();
    let profile = LlmProfile::deepseek_v3();
    let mut failures: Vec<String> = Vec::new();
    let mut rows: Vec<Row> = Vec::new();

    for (label, seq, page, window) in [
        ("sweep_256_page16_win64", 256usize, 16usize, 64usize),
        ("sweep_512_page32_win128", 512, 32, 128),
    ] {
        let mut base = OpSpec::benchmark(AttnVariant::Mha, seq, 64, true);
        base.batch = 1;
        let paged_spec = base.with_layout(KvLayout::Paged { page_size: page });
        let sliding_spec = base.with_layout(KvLayout::Sliding { window });

        let contiguous = generate_tl_code(&base, &arch, &profile).program;
        let paged = generate_tl_code(&paged_spec, &arch, &profile).program;
        let sliding = generate_tl_code(&sliding_spec, &arch, &profile).program;

        let q = Tensor2::randn(seq, 64, 1);
        let k = Tensor2::randn(seq, 64, 2);
        let v = Tensor2::randn(seq, 64, 3);
        let scale = 1.0 / 8.0;

        let mut tables = BTreeMap::new();
        tables.insert("block_table".to_string(), identity_table(seq / page));
        let (kp, vp, table) = paged_shuffle(&k, &v, page, 0xBEEF);

        // Bit-identity gate before timing anything: paged over the
        // identity table must reproduce the contiguous bits exactly.
        let want = run_attention_threads(&contiguous, &q, &k, &v, scale, 1).unwrap();
        for t in [1usize, threads] {
            let got = run_attention_tables(&paged, &q, &k, &v, scale, &tables, t).unwrap();
            if got.data != want.data {
                failures.push(format!("{label}: paged(identity, {t}t) != contiguous"));
            }
        }
        {
            let mut shuffled_tables = tables.clone();
            shuffled_tables.insert("block_table".to_string(), table.clone());
            let got =
                run_attention_tables(&paged, &q, &kp, &vp, scale, &shuffled_tables, 1)
                    .unwrap();
            if got.data != want.data {
                failures.push(format!("{label}: paged(shuffle) != contiguous"));
            }
        }

        let mut shuffled = BTreeMap::new();
        shuffled.insert("block_table".to_string(), table);

        let c1 = Bench::new(format!("layout_contiguous_1t_{label}"))
            .warmup(1)
            .samples(samples)
            .run(|| run_attention_threads(&contiguous, &q, &k, &v, scale, 1).unwrap());
        let p1 = Bench::new(format!("layout_paged_1t_{label}"))
            .warmup(1)
            .samples(samples)
            .run(|| {
                run_attention_tables(&paged, &q, &kp, &vp, scale, &shuffled, 1).unwrap()
            });
        let s1 = Bench::new(format!("layout_sliding_1t_{label}"))
            .warmup(1)
            .samples(samples)
            .run(|| run_attention_threads(&sliding, &q, &k, &v, scale, 1).unwrap());
        let cn = Bench::new(format!("layout_contiguous_{threads}t_{label}"))
            .warmup(1)
            .samples(samples)
            .run(|| run_attention_threads(&contiguous, &q, &k, &v, scale, threads).unwrap());
        let pn = Bench::new(format!("layout_paged_{threads}t_{label}"))
            .warmup(1)
            .samples(samples)
            .run(|| {
                run_attention_tables(&paged, &q, &kp, &vp, scale, &shuffled, threads)
                    .unwrap()
            });

        let row = Row {
            label,
            contiguous_us: c1.mean.as_secs_f64() * 1e6,
            paged_us: p1.mean.as_secs_f64() * 1e6,
            sliding_us: s1.mean.as_secs_f64() * 1e6,
            contiguous_nt_us: cn.mean.as_secs_f64() * 1e6,
            paged_nt_us: pn.mean.as_secs_f64() * 1e6,
        };
        println!(
            "  -> {label}: paged/contiguous = {:.2}x, sliding/contiguous = {:.2}x, paged 1t/{threads}t = {:.2}x",
            row.paged_us / row.contiguous_us,
            row.sliding_us / row.contiguous_us,
            row.paged_us / row.paged_nt_us,
        );
        rows.push(row);
    }

    let mut json = format!(
        "{{\n  \"mode\": \"{}\",\n  \"threads\": {threads},\n  \"sweeps\": [\n",
        if smoke { "smoke" } else { "full" }
    );
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"label\": \"{}\", \"contiguous_us\": {:.1}, \"paged_us\": {:.1}, \
             \"sliding_us\": {:.1}, \"contiguous_nt_us\": {:.1}, \"paged_nt_us\": {:.1}, \
             \"gather_overhead\": {:.3}, \"window_speedup\": {:.2}}}{}\n",
            r.label,
            r.contiguous_us,
            r.paged_us,
            r.sliding_us,
            r.contiguous_nt_us,
            r.paged_nt_us,
            r.paged_us / r.contiguous_us,
            r.contiguous_us / r.sliding_us,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    let max_overhead = rows
        .iter()
        .map(|r| r.paged_us / r.contiguous_us)
        .fold(0.0f64, f64::max);
    json.push_str(&format!("  ],\n  \"max_gather_overhead\": {max_overhead:.3}\n}}\n"));
    if let Err(e) = std::fs::write("BENCH_paged.json", &json) {
        eprintln!("warning: could not write BENCH_paged.json: {e}");
    } else {
        println!("recorded BENCH_paged.json:\n{json}");
    }

    // Regressions: numeric divergence always fails; in CI (smoke mode) a
    // host-side gather must also stay within a small constant factor of
    // the dense load (generous bound — CI machines are noisy). Full
    // local runs report the overhead without gating on it.
    if smoke && max_overhead > 3.0 {
        failures.push(format!(
            "paged gather {max_overhead:.2}x slower than contiguous (cap 3.0x)"
        ));
    }
    if !failures.is_empty() {
        eprintln!("paged bench FAILED:");
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
}
