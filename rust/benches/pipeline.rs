//! Pipeline benches — the Table-4 "development cost" measurement: wall
//! clock of sketch → reason → verify → translate, per stage and end to
//! end. DESIGN.md §7 target: full pipeline < 50 ms in release mode
//! (vs ~10 minutes with a live LLM, vs months for a human expert).

use qimeng::perfmodel::gpu::GpuArch;
use qimeng::pipeline::{run, Target};
use qimeng::reasoner::profiles::LlmProfile;
use qimeng::reasoner::{generate_tl_code, reason};
use qimeng::sketch::{generate_sketch, spec::{AttnVariant, OpSpec}};
use qimeng::translate::{pallas::PallasBackend, Backend};
use qimeng::util::bench::Bench;
use qimeng::verify::verify_program;

fn main() {
    let spec = OpSpec::benchmark(AttnVariant::Mha, 16384, 128, true);
    let arch = GpuArch::a100();
    let profile = LlmProfile::deepseek_r1();

    Bench::new("sketch_generation").samples(200).run(|| generate_sketch(&spec));

    let sketch = generate_sketch(&spec);
    Bench::new("parameter_reasoning").samples(200).run(|| {
        reason(&sketch, &spec, &arch, &profile)
    });

    let reasoned = reason(&sketch, &spec, &arch, &profile);
    Bench::new("verification_gate").samples(20).run(|| {
        verify_program(&reasoned.program, spec.causal, 7)
    });

    Bench::new("pallas_translation").samples(200).run(|| {
        PallasBackend.emit(&reasoned, &spec, &arch).unwrap()
    });

    let report = Bench::new("full_pipeline_end_to_end").samples(20).run(|| {
        run(&spec, &arch, &profile, Target::Pallas).unwrap()
    });
    let target = std::time::Duration::from_millis(50);
    println!(
        "full pipeline mean {:?} — target {:?}: {}",
        report.mean,
        target,
        if report.mean < target { "MET" } else { "MISSED" }
    );
}
