//! Design-choice ablations (DESIGN.md §5, Listings 1-2 context): each
//! knob the reasoner controls, isolated and measured two ways — modeled
//! GPU TFLOPS (A100) and, where it changes generated code, real pipeline
//! wall-clock.
//!
//!   * tiling strategy: one-shot heuristic vs cost-model search
//!   * double-buffer prefetch: on vs off
//!   * causal block skipping: on vs off
//!   * softmax/mma overlap sensitivity

use qimeng::perfmodel::cost::estimate;
use qimeng::perfmodel::gpu::GpuArch;
use qimeng::perfmodel::schedules;
use qimeng::reasoner::tiling::{choose, TilingStrategy};
use qimeng::sketch::spec::{AttnVariant, OpSpec};
use qimeng::tl::types::DType;
use qimeng::util::bench::Bench;

fn main() {
    let arch = GpuArch::a100();

    println!("== ablation: tiling strategy (A100, modeled TFLOPS @16k causal) ==");
    for hd in [64usize, 128] {
        let spec = OpSpec::benchmark(AttnVariant::Mha, 16384, hd, true);
        for (name, strat) in
            [("heuristic", TilingStrategy::Heuristic), ("cost-search", TilingStrategy::CostSearch)]
        {
            let t = choose(strat, &spec, &arch, true);
            let mut sched = schedules::ours(&arch, hd, DType::F16);
            sched.bm = t.bm;
            sched.bn = t.bn;
            let est = estimate(&spec, &arch, &sched);
            println!(
                "  hd{hd:<4} {name:<12} BM={:<4} BN={:<4} smem={:<6} blocks/SM={} -> {:.1} TFLOPS",
                t.bm, t.bn, t.smem_bytes, t.blocks_per_sm, est.tflops
            );
        }
    }

    println!("\n== ablation: double-buffer prefetch (modeled; Listing-1 knob) ==");
    for hd in [64usize, 128] {
        let spec = OpSpec::benchmark(AttnVariant::Mha, 16384, hd, true);
        let with = schedules::ours(&arch, hd, DType::F16);
        let mut without = with.clone();
        without.softmax_overlap -= 0.25; // staging exposed without the prefetch
        let a = estimate(&spec, &arch, &with).tflops;
        let b = estimate(&spec, &arch, &without).tflops;
        println!("  hd{hd:<4} prefetch on {a:.1} | off {b:.1} TFLOPS ({:+.1}%)", (a / b - 1.0) * 100.0);
    }

    println!("\n== ablation: causal block skipping (modeled) ==");
    for seq in [2048usize, 16384] {
        let spec = OpSpec::benchmark(AttnVariant::Mha, seq, 64, true);
        let skip = schedules::ours(&arch, 64, DType::F16);
        let mut no_skip = skip.clone();
        no_skip.causal_block_skip = false;
        let a = estimate(&spec, &arch, &skip).tflops;
        let b = estimate(&spec, &arch, &no_skip).tflops;
        println!("  seq {seq:<6} skip {a:.1} | visit-all {b:.1} TFLOPS ({:.2}x)", a / b);
    }

    println!("\n== ablation: softmax overlap sensitivity (modeled, hd64 @16k) ==");
    let spec = OpSpec::benchmark(AttnVariant::Mha, 16384, 64, true);
    for overlap in [0.0, 0.4, 0.8] {
        let mut sched = schedules::ours(&arch, 64, DType::F16);
        sched.softmax_overlap = overlap;
        let est = estimate(&spec, &arch, &sched);
        println!("  overlap {overlap:.1} -> {:.1} TFLOPS", est.tflops);
    }

    println!("\n== real pipeline cost of the search (generation wall-clock) ==");
    use qimeng::reasoner::profiles::LlmProfile;
    let spec = OpSpec::benchmark(AttnVariant::Mha, 16384, 128, true);
    for profile in [LlmProfile::deepseek_v3(), LlmProfile::deepseek_r1()] {
        let sk = qimeng::sketch::generate_sketch(&spec);
        Bench::new(format!("reasoning_{}", profile.name)).samples(100).run(|| {
            qimeng::reasoner::reason(&sk, &spec, &arch, &profile)
        });
    }
}
