//! Numeric TL engine benches: legacy statement walker vs the compiled
//! block engine, single-thread and parallel, SIMD vs forced-scalar
//! kernels, and per-head vs head-batched sweeps. §Perf tracks the
//! per-probe cost since every `tlc generate` pays it (and the serving
//! oracle pays it per batch).
//!
//! Modes:
//!   cargo bench --bench interpreter              full run
//!   cargo bench --bench interpreter -- --smoke   fewer samples (CI):
//!       verifies walker/compiled bit-identity on every sweep point —
//!       including SIMD-vs-scalar dispatch and the head-batched driver —
//!       fails on any mismatch, and records BENCH_interp.json with the
//!       walker-vs-compiled, 1-vs-N-thread, scalar-vs-SIMD and
//!       per-head-vs-head-batched speedups. CI runs the smoke in both
//!       the default and the QIMENG_SIMD=0 environments.

use qimeng::perfmodel::gpu::GpuArch;
use qimeng::reasoner::generate_tl_code;
use qimeng::reasoner::profiles::LlmProfile;
use qimeng::sketch::spec::{AttnVariant, OpSpec};
use qimeng::util::bench::Bench;
use qimeng::verify::exec::{self, default_threads, run_attention_threads, AttnHead};
use qimeng::verify::interp::run_attention as run_walker;
use qimeng::verify::tensor::{reference_attention, set_simd_enabled, simd_enabled, Tensor2};

/// Heads per head-batched sweep (enough tasks to feed every worker).
const HEADS: usize = 4;

struct Row {
    label: &'static str,
    walker_us: f64,
    compiled_1t_us: f64,
    compiled_nt_us: f64,
    /// Compiled 1-thread with SIMD dispatch forced off.
    scalar_1t_us: f64,
    /// `HEADS` heads swept one prepared-program call per head.
    per_head_us: f64,
    /// Same heads through one flattened `run_heads` sweep.
    head_batched_us: f64,
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let samples = if smoke { 5 } else { 20 };
    let threads = default_threads().max(2);
    let arch = GpuArch::a100();
    // Ambient dispatch mode (honors QIMENG_SIMD=0); every timed section
    // below restores it, and the scalar A/B forces the fallback.
    let simd_on = simd_enabled();
    let mut failures: Vec<String> = Vec::new();
    let mut rows: Vec<Row> = Vec::new();

    for (label, seq, hd, causal) in [
        ("sweep_256_hd64_causal", 256usize, 64usize, true),
        ("sweep_512_hd128_causal", 512, 128, true),
        ("sweep_1024_hd64_full", 1024, 64, false),
    ] {
        let mut spec = OpSpec::benchmark(AttnVariant::Mha, seq, hd, causal);
        spec.batch = 1;
        let r = generate_tl_code(&spec, &arch, &LlmProfile::deepseek_v3());
        let q = Tensor2::randn(seq, spec.qk_dim(), 1);
        let k = Tensor2::randn(seq, spec.qk_dim(), 2);
        let v = Tensor2::randn(seq, spec.v_head_dim, 3);
        let scale = 1.0 / (spec.qk_dim() as f32).sqrt();
        let no_tables = std::collections::BTreeMap::new();

        // Bit-identity gates before timing anything: a fast wrong engine
        // is worse than a slow right one.
        let want = run_walker(&r.program, &q, &k, &v, scale).unwrap();
        for t in [1usize, threads] {
            let got = run_attention_threads(&r.program, &q, &k, &v, scale, t).unwrap();
            if got.data != want.data {
                failures.push(format!(
                    "{label}: compiled engine ({t} threads) diverged from the walker"
                ));
            }
        }
        // SIMD-vs-scalar: the dispatch modes are bit-identical by
        // construction, so the forced-fallback run must match the
        // ambient-mode walker output bit for bit.
        set_simd_enabled(false);
        let scalar_got = run_attention_threads(&r.program, &q, &k, &v, scale, 1).unwrap();
        set_simd_enabled(simd_on);
        if scalar_got.data != want.data {
            failures.push(format!(
                "{label}: forced-scalar kernels diverged from the ambient dispatch mode"
            ));
        }
        // Head-batched sweep: flattening (head, block) tasks must change
        // scheduling only, never bits — at any worker count.
        let prepared = exec::prepare(&r.program).unwrap();
        let hqkv: Vec<(Tensor2, Tensor2, Tensor2)> = (0..HEADS)
            .map(|h| {
                (
                    Tensor2::randn(seq, spec.qk_dim(), 10 + h as u64),
                    Tensor2::randn(seq, spec.qk_dim(), 20 + h as u64),
                    Tensor2::randn(seq, spec.v_head_dim, 30 + h as u64),
                )
            })
            .collect();
        let heads: Vec<AttnHead<'_>> =
            hqkv.iter().map(|(q, k, v)| AttnHead { q, k, v }).collect();
        let per_head_want: Vec<Tensor2> = hqkv
            .iter()
            .map(|(q, k, v)| prepared.run_attention(q, k, v, scale, &no_tables, 1).unwrap())
            .collect();
        for t in [1usize, threads] {
            let batched = prepared.run_heads(&heads, scale, &no_tables, t).unwrap();
            for (h, (got, want)) in batched.iter().zip(&per_head_want).enumerate() {
                if got.data != want.data {
                    failures.push(format!(
                        "{label}: head-batched sweep ({t} threads) diverged on head {h}"
                    ));
                }
            }
        }

        let walker = Bench::new(format!("tl_walker_{label}"))
            .warmup(1)
            .samples(samples)
            .run(|| run_walker(&r.program, &q, &k, &v, scale).unwrap());
        let compiled_1t = Bench::new(format!("tl_compiled_1t_{label}"))
            .warmup(1)
            .samples(samples)
            .run(|| run_attention_threads(&r.program, &q, &k, &v, scale, 1).unwrap());
        let compiled_nt = Bench::new(format!("tl_compiled_{threads}t_{label}"))
            .warmup(1)
            .samples(samples)
            .run(|| run_attention_threads(&r.program, &q, &k, &v, scale, threads).unwrap());
        set_simd_enabled(false);
        let scalar_1t = Bench::new(format!("tl_scalar_1t_{label}"))
            .warmup(1)
            .samples(samples)
            .run(|| run_attention_threads(&r.program, &q, &k, &v, scale, 1).unwrap());
        set_simd_enabled(simd_on);
        let per_head = Bench::new(format!("tl_per_head_{HEADS}h_{label}"))
            .warmup(1)
            .samples(samples)
            .run(|| {
                for (q, k, v) in &hqkv {
                    prepared.run_attention(q, k, v, scale, &no_tables, threads).unwrap();
                }
            });
        let head_batched = Bench::new(format!("tl_head_batched_{HEADS}h_{label}"))
            .warmup(1)
            .samples(samples)
            .run(|| prepared.run_heads(&heads, scale, &no_tables, threads).unwrap());
        Bench::new(format!("host_reference_{label}"))
            .warmup(1)
            .samples(samples)
            .run(|| reference_attention(&q, &k, &v, scale, causal));

        let row = Row {
            label,
            walker_us: walker.mean.as_secs_f64() * 1e6,
            compiled_1t_us: compiled_1t.mean.as_secs_f64() * 1e6,
            compiled_nt_us: compiled_nt.mean.as_secs_f64() * 1e6,
            scalar_1t_us: scalar_1t.mean.as_secs_f64() * 1e6,
            per_head_us: per_head.mean.as_secs_f64() * 1e6,
            head_batched_us: head_batched.mean.as_secs_f64() * 1e6,
        };
        println!(
            "  -> {label}: walker/compiled(1t) = {:.2}x, 1t/{threads}t = {:.2}x, \
             scalar/simd(1t) = {:.2}x, per-head/batched({HEADS}h) = {:.2}x",
            row.walker_us / row.compiled_1t_us,
            row.compiled_1t_us / row.compiled_nt_us,
            row.scalar_1t_us / row.compiled_1t_us,
            row.per_head_us / row.head_batched_us,
        );
        rows.push(row);
    }

    // Record results where CI can diff them (perf trajectory file).
    let mut json = format!(
        "{{\n  \"mode\": \"{}\",\n  \"threads\": {threads},\n  \"simd\": {simd_on},\n  \
         \"heads\": {HEADS},\n  \"sweeps\": [\n",
        if smoke { "smoke" } else { "full" }
    );
    for (i, row) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"label\": \"{}\", \"walker_us\": {:.1}, \"compiled_1t_us\": {:.1}, \
             \"compiled_nt_us\": {:.1}, \"scalar_1t_us\": {:.1}, \"per_head_us\": {:.1}, \
             \"head_batched_us\": {:.1}, \"speedup_1t\": {:.2}, \"speedup_nt\": {:.2}, \
             \"simd_speedup_1t\": {:.2}, \"head_batch_speedup\": {:.2}}}{}\n",
            row.label,
            row.walker_us,
            row.compiled_1t_us,
            row.compiled_nt_us,
            row.scalar_1t_us,
            row.per_head_us,
            row.head_batched_us,
            row.walker_us / row.compiled_1t_us,
            row.walker_us / row.compiled_nt_us,
            row.scalar_1t_us / row.compiled_1t_us,
            row.per_head_us / row.head_batched_us,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    let min_1t = rows
        .iter()
        .map(|r| r.walker_us / r.compiled_1t_us)
        .fold(f64::INFINITY, f64::min);
    let min_nt = rows
        .iter()
        .map(|r| r.walker_us / r.compiled_nt_us)
        .fold(f64::INFINITY, f64::min);
    let min_simd = rows
        .iter()
        .map(|r| r.scalar_1t_us / r.compiled_1t_us)
        .fold(f64::INFINITY, f64::min);
    let min_batch = rows
        .iter()
        .map(|r| r.per_head_us / r.head_batched_us)
        .fold(f64::INFINITY, f64::min);
    json.push_str(&format!(
        "  ],\n  \"min_speedup_1t\": {min_1t:.2},\n  \"min_speedup_nt\": {min_nt:.2},\n  \
         \"min_simd_speedup_1t\": {min_simd:.2},\n  \
         \"min_head_batch_speedup\": {min_batch:.2}\n}}\n"
    ));
    if let Err(e) = std::fs::write("BENCH_interp.json", &json) {
        eprintln!("warning: could not write BENCH_interp.json: {e}");
    } else {
        println!("recorded BENCH_interp.json:\n{json}");
    }

    // Regressions that fail the bench: numeric divergence always; the
    // compiled engine falling behind the walker it replaces. The SIMD
    // and head-batch speedups are recorded for the perf trajectory but
    // not hard-gated — under QIMENG_SIMD=0 (one of the CI modes) the
    // scalar/simd ratio is 1.0 by construction, and wall-clock ratios on
    // shared CI runners are too noisy for a strict floor.
    if min_1t < 1.0 {
        failures.push(format!(
            "compiled engine slower than the legacy walker (min speedup {min_1t:.2}x)"
        ));
    }
    if !failures.is_empty() {
        eprintln!("interpreter bench FAILED:");
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
}
