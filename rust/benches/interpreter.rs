//! Numeric TL interpreter benches: the verification gate's hot path
//! (O(n^3) host matmuls). §Perf tracks the per-probe cost since every
//! `tlc generate` pays it.

use qimeng::perfmodel::gpu::GpuArch;
use qimeng::reasoner::generate_tl_code;
use qimeng::reasoner::profiles::LlmProfile;
use qimeng::sketch::spec::{AttnVariant, OpSpec};
use qimeng::util::bench::Bench;
use qimeng::verify::interp::run_attention;
use qimeng::verify::tensor::{reference_attention, Tensor2};

fn main() {
    let arch = GpuArch::a100();
    for (label, seq, hd) in
        [("probe_256_hd64", 256usize, 64usize), ("probe_512_hd128", 512, 128)]
    {
        let mut spec = OpSpec::benchmark(AttnVariant::Mha, seq, hd, true);
        spec.batch = 1;
        let r = generate_tl_code(&spec, &arch, &LlmProfile::deepseek_v3());
        let q = Tensor2::randn(seq, spec.qk_dim(), 1);
        let k = Tensor2::randn(seq, spec.qk_dim(), 2);
        let v = Tensor2::randn(seq, spec.v_head_dim, 3);
        let scale = 1.0 / (spec.qk_dim() as f32).sqrt();
        Bench::new(format!("tl_interpreter_{label}")).samples(10).run(|| {
            run_attention(&r.program, &q, &k, &v, scale).unwrap()
        });
        Bench::new(format!("host_reference_{label}")).samples(10).run(|| {
            reference_attention(&q, &k, &v, scale, true)
        });
    }
}
