//! Offline drop-in subset of the `anyhow` crate.
//!
//! crates.io is unreachable in this environment (DESIGN.md §2), so the
//! workspace vendors the slice of the API the codebase actually uses:
//! [`Error`] with a context chain, the [`Context`] extension trait for
//! `Result` and `Option`, the [`Result`] alias, and the [`anyhow!`] /
//! [`bail!`] macros. Semantics follow upstream anyhow: `Display` prints
//! the outermost message, `{:#}` prints the whole chain separated by
//! `: `, and `Debug` prints the message plus a `Caused by:` list.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the upstream default-parameter shape.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error with a chain of context messages (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (what upstream's
    /// `Error::context` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context/source messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost message (what `Display` prints).
    pub fn root_message(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain on one line, as upstream does.
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.root_message())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.root_message())?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// As in upstream anyhow, `Error` deliberately does NOT implement
// `std::error::Error`: that keeps this blanket conversion coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait attaching context to `Result` and `Option` values.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, format string, or error value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file gone")
    }

    #[test]
    fn context_chain_renders_alternate() {
        let r: Result<()> = Err(io_err()).context("opening manifest");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "opening manifest");
        assert_eq!(format!("{e:#}"), "opening manifest: file gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert!(format!("{e:#}").contains("missing field"));
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn with_context_lazy() {
        let r: Result<(), _> = Err(io_err());
        let e = r.with_context(|| format!("artifact {}", "a1")).unwrap_err();
        assert!(format!("{e:#}").starts_with("artifact a1"));
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let e = anyhow!("x = {}", 7);
        assert_eq!(format!("{e}"), "x = 7");
        let s = String::from("owned message");
        let e = anyhow!(s);
        assert_eq!(format!("{e}"), "owned message");
        fn fails() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert!(fails().is_err());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<usize> {
            let n: usize = s.parse()?;
            Ok(n)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("x").is_err());
    }

    #[test]
    fn debug_shows_cause_list() {
        let r: Result<()> = Err(io_err()).context("outer");
        let dbg = format!("{:?}", r.unwrap_err());
        assert!(dbg.contains("outer"));
        assert!(dbg.contains("Caused by"));
        assert!(dbg.contains("file gone"));
    }
}
