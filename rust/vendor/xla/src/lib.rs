//! Offline stub of the `xla` crate (PJRT C-API bindings).
//!
//! The real crate links the XLA PJRT runtime, which is unavailable in
//! this build environment (DESIGN.md §2). This stub mirrors the exact
//! API surface `qimeng::runtime` uses so the crate compiles and the
//! error paths stay honest:
//!
//! * [`PjRtClient::cpu`] succeeds (so registries/coordinators can open
//!   and parse manifests),
//! * [`HloModuleProto::from_text_file`] reads and shallowly validates
//!   HLO text,
//! * [`PjRtClient::compile`] always fails with a clear "stubbed PJRT"
//!   error, which the artifact-gated tests and benches already treat as
//!   a skip/failure path.
//!
//! Swapping back to the real crate is a one-line Cargo.toml change; no
//! source edits are required.

use std::fmt;

/// Error type matching the real crate's `Error: std::error::Error`.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const STUB_MSG: &str = "PJRT runtime unavailable: built against the vendored xla stub \
     (swap rust/vendor/xla for the real crate to execute artifacts)";

/// Element types a [`Literal`] can carry.
pub trait NativeType: Copy + 'static {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Marker trait for values accepted by [`PjRtLoadedExecutable::execute`].
pub trait BufferArgument {}

impl BufferArgument for Literal {}

/// Host-side tensor value. The stub tracks only the element count and
/// shape so `reshape` can validate like the real crate does.
#[derive(Debug, Clone)]
pub struct Literal {
    elements: usize,
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { elements: data.len(), dims: vec![data.len() as i64] }
    }

    /// Reshape; errors when the element counts disagree (the one check
    /// the real crate performs eagerly on the host).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n < 0 || n as usize != self.elements {
            return Err(Error(format!(
                "reshape: {} elements do not fit shape {dims:?}",
                self.elements
            )));
        }
        Ok(Literal { elements: self.elements, dims: dims.to_vec() })
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error(STUB_MSG.to_string()))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error(STUB_MSG.to_string()))
    }
}

/// Parsed HLO module (text retained, structure unvalidated).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))?;
        if !text.trim_start().starts_with("HloModule") {
            return Err(Error(format!("{path}: not HLO text (missing HloModule header)")));
        }
        Ok(HloModuleProto { text })
    }
}

/// A computation ready to compile.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle returned by `execute`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error(STUB_MSG.to_string()))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: BufferArgument>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(STUB_MSG.to_string()))
    }
}

/// PJRT client handle. Creation succeeds so manifest-level code paths
/// (registry open, coordinator startup) work; compilation fails.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu (stub)".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(STUB_MSG.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creates_but_compile_is_stubbed() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        assert!(client.compile(&XlaComputation).is_err());
    }

    #[test]
    fn literal_reshape_validates_counts() {
        let l = Literal::vec1(&[1.0f32; 12]);
        assert!(l.reshape(&[3, 4]).is_ok());
        assert!(l.reshape(&[5, 5]).is_err());
    }

    #[test]
    fn hlo_text_header_checked() {
        let dir = std::env::temp_dir().join("xla_stub_test");
        std::fs::create_dir_all(&dir).unwrap();
        let good = dir.join("good.hlo.txt");
        std::fs::write(&good, "HloModule m\n").unwrap();
        assert!(HloModuleProto::from_text_file(good.to_str().unwrap()).is_ok());
        let bad = dir.join("bad.hlo.txt");
        std::fs::write(&bad, "not hlo at all").unwrap();
        assert!(HloModuleProto::from_text_file(bad.to_str().unwrap()).is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
    }
}
