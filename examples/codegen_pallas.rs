//! Code generation across the operator zoo: generate TL + Pallas + CuTe
//! for every variant/GPU the paper evaluates, including the Appendix-B
//! single-stage ablation (which the verifier must reject).
//!
//! ```sh
//! cargo run --release --example codegen_pallas
//! ```

use qimeng::perfmodel::gpu::GpuArch;
use qimeng::pipeline::{run, PipelineError, Target};
use qimeng::reasoner::profiles::{FailureMode, LlmProfile};
use qimeng::sketch::spec::{AttnVariant, OpSpec};

fn main() {
    let out_dir = std::env::temp_dir().join("qimeng_codegen_demo");
    std::fs::create_dir_all(&out_dir).unwrap();

    println!("== generating across GPUs and variants ==");
    for arch in [GpuArch::a100(), GpuArch::rtx8000(), GpuArch::t4(), GpuArch::l40s()] {
        for variant in [AttnVariant::Mha, AttnVariant::Gqa, AttnVariant::Mqa, AttnVariant::Mla]
        {
            let spec = match variant {
                AttnVariant::Mla => OpSpec::mla(2048, true),
                v => OpSpec::benchmark(v, 2048, 128, true),
            };
            for target in [Target::Pallas, Target::Cute] {
                let tname = if target == Target::Pallas { "pallas" } else { "cute" };
                match run(&spec, &arch, &LlmProfile::deepseek_r1(), target) {
                    Ok(r) => {
                        let ext = if target == Target::Pallas { "py" } else { "cu" };
                        let path = out_dir.join(format!(
                            "{}_{}.{ext}",
                            spec.kernel_name(),
                            arch.name.to_lowercase()
                        ));
                        std::fs::write(&path, r.source.unwrap()).unwrap();
                        println!(
                            "  {:<22} {:<8} {:<7} BM={:<3} BN={:<3} verified {:.1e}  -> {}",
                            spec.kernel_name(),
                            arch.name,
                            tname,
                            r.reasoned.tiling.bm,
                            r.reasoned.tiling.bn,
                            r.verify.max_abs_diff.unwrap_or(f32::NAN),
                            path.display()
                        );
                    }
                    Err(e) => println!(
                        "  {:<22} {:<8} {:<7} SKIPPED: {e}",
                        spec.kernel_name(),
                        arch.name,
                        tname
                    ),
                }
            }
        }
    }

    println!("\n== Appendix-B ablation: single-stage generation must be rejected ==");
    for failure in [FailureMode::ReshapeOmission, FailureMode::GemmLayoutError] {
        let profile = LlmProfile::single_stage(LlmProfile::deepseek_v3(), failure);
        let spec = OpSpec::benchmark(AttnVariant::Mha, 2048, 64, true);
        match run(&spec, &GpuArch::a100(), &profile, Target::Pallas) {
            Err(PipelineError::VerifyFailed(report)) => {
                println!("  {failure:?}: rejected with {} diagnostic(s):", report.diagnostics.len());
                for d in &report.diagnostics {
                    println!("    {d}");
                }
            }
            other => println!("  {failure:?}: UNEXPECTED: {other:?}"),
        }
    }
}
