//! Autotuner demo: the paper's self-optimizing loop end to end.
//!
//! 1. Search the schedule space for a few operators on every GPU the
//!    paper evaluates, comparing the winner against the legacy
//!    heuristic / cost-search tilings on the shared cost model.
//! 2. Persist the winners in a tuning cache and run the whole sweep
//!    again to show the zero-cost cached path.
//! 3. Feed the tuned schedule through the full pipeline
//!    (`pipeline::run_tuned`) so the searched BM/BN land in verified,
//!    translated kernel code.
//!
//! ```sh
//! cargo run --release --example autotune
//! ```

use std::time::Instant;

use qimeng::autotune::space::{self, Candidate};
use qimeng::autotune::{AutotuneConfig, Autotuner};
use qimeng::perfmodel::gpu::GpuArch;
use qimeng::pipeline::{run_tuned, Target};
use qimeng::reasoner::profiles::LlmProfile;
use qimeng::reasoner::tiling::{choose, TilingStrategy};
use qimeng::sketch::spec::{AttnVariant, OpSpec};

fn main() {
    let cache_path = std::env::temp_dir().join("qimeng_autotune_demo").join("tune.txt");
    let _ = std::fs::remove_file(&cache_path);
    let config = AutotuneConfig { cache_path: Some(cache_path.clone()), ..Default::default() };

    let specs: Vec<(&str, OpSpec)> = vec![
        ("mha hd64 @4k causal", OpSpec::benchmark(AttnVariant::Mha, 4096, 64, true)),
        ("gqa hd128 @16k causal", OpSpec::benchmark(AttnVariant::Gqa, 16384, 128, true)),
        ("mla @8k causal", OpSpec::mla(8192, true)),
    ];

    println!("== autotune vs legacy strategies (modeled us, lower is better) ==");
    let mut tuner = Autotuner::new(config.clone()).expect("tuner");
    for arch in GpuArch::all() {
        for (label, spec) in &specs {
            let r = tuner.tune(spec, &arch, Target::Pallas);
            let legacy_us = |strategy: TilingStrategy| {
                let c = Candidate::from_tiling(&choose(strategy, spec, &arch, true));
                space::model_seconds(spec, &arch, &c) * 1e6
            };
            println!(
                "{:<8} {:<24} heuristic {:>9.1}  cost-search {:>9.1}  autotune {:>9.1}  [{}]",
                arch.name,
                label,
                legacy_us(TilingStrategy::Heuristic),
                legacy_us(TilingStrategy::CostSearch),
                r.seconds * 1e6,
                r.candidate,
            );
        }
    }
    tuner.save().expect("save cache");
    println!(
        "\nsearched {} configurations -> {}",
        tuner.cache().len(),
        cache_path.display()
    );

    println!("\n== second sweep: persistent cache ==");
    let mut warm = Autotuner::new(config).expect("tuner reload");
    let t0 = Instant::now();
    for arch in GpuArch::all() {
        for (_, spec) in &specs {
            let r = warm.tune(spec, &arch, Target::Pallas);
            assert!(r.cached, "warm sweep must hit the cache");
        }
    }
    println!(
        "{} lookups in {:.2?} — {} hits, {} misses",
        GpuArch::all().len() * specs.len(),
        t0.elapsed(),
        warm.cache().hits(),
        warm.cache().misses()
    );

    println!("\n== tuned schedule through the full pipeline ==");
    let spec = OpSpec::benchmark(AttnVariant::Mha, 4096, 64, true);
    let result = run_tuned(
        &spec,
        &GpuArch::a100(),
        &LlmProfile::deepseek_v3(),
        Target::Pallas,
        &mut warm,
    )
    .expect("tuned pipeline");
    let tune = result.tune.as_ref().unwrap();
    println!(
        "verified {} with searched tiling BM={} BN={} (diff {:.2e}); \
         search {:.2?} ({}), pipeline total {:.2?}",
        spec.kernel_name(),
        result.reasoned.tiling.bm,
        result.reasoned.tiling.bn,
        result.verify.max_abs_diff.unwrap_or(f32::NAN),
        result.timings.search,
        if tune.cached { "cache hit" } else { tune.strategy },
        result.timings.total(),
    );
}
