//! End-to-end driver (the repository's E2E validation): serve batched
//! attention requests through the full stack —
//!
//!   tlc-generated Pallas kernels → AOT HLO artifacts → rust PJRT
//!   runtime → signature batcher → responses — with correctness checked
//!   against the rust-side reference oracle and latency/throughput
//!   reported (recorded in EXPERIMENTS.md §E2E).
//!
//! Requires `make artifacts` first.
//!
//! ```sh
//! cargo run --release --example serve_attention
//! ```

use std::time::Duration;

use qimeng::coordinator::{run_stream, Coordinator, ServeConfig};
use qimeng::verify::tensor::{reference_attention, Tensor2};
use qimeng::workload::{request_stream, SyntheticRequest};

fn main() {
    let config = ServeConfig {
        artifacts_dir: "artifacts".into(),
        batch_window: Duration::from_millis(5),
        ..ServeConfig::default()
    };
    let coordinator = match Coordinator::start(config) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("failed to start coordinator (run `make artifacts` first): {e:#}");
            std::process::exit(1);
        }
    };
    println!(
        "coordinator up: {} servable attention families",
        coordinator.families.len()
    );
    for f in coordinator.families.iter().take(4) {
        println!("  e.g. {:?} causal={} qk={} heads {}/{}", f.variant, f.causal, f.qk_dim, f.q_heads, f.kv_heads);
    }

    // -- correctness spot check through the full serving path --
    println!("\n== correctness: served output vs rust reference oracle ==");
    let fam = coordinator
        .families
        .iter()
        .find(|f| f.causal && f.qk_dim == 64)
        .expect("no causal hd64 family")
        .clone();
    let req = SyntheticRequest { family: fam.clone(), seed: 2024, arrival: Duration::ZERO };
    let (q, k, v) = req.payload();
    let rx = coordinator.submit(fam.clone(), q.clone(), k.clone(), v.clone());
    let resp = rx.recv().expect("no response");
    let out = resp.outcome.into_result().expect("serve error");
    // Compare head 0 (per-head slices; GQA maps q-head h -> kv-head h/g).
    let (s, d, vd) = (fam.seq, fam.qk_dim, fam.v_dim);
    let qt = Tensor2 { rows: s, cols: d, data: q[..s * d].to_vec() };
    let kt = Tensor2 { rows: s, cols: d, data: k[..s * d].to_vec() };
    let vt = Tensor2 { rows: s, cols: vd, data: v[..s * vd].to_vec() };
    let want = reference_attention(&qt, &kt, &vt, 1.0 / (d as f32).sqrt(), true);
    let got = Tensor2 { rows: s, cols: vd, data: out[..s * vd].to_vec() };
    let diff = got.max_abs_diff(&want);
    println!("  max |served - reference| = {diff:.3e}  ({})", if diff < 5e-4 { "OK" } else { "MISMATCH" });
    assert!(diff < 5e-4);

    // -- warm the executables (compile on first use), one per family --
    println!("\n== warmup: compiling every family's executables ==");
    let t0 = std::time::Instant::now();
    let warm_rxs: Vec<_> = coordinator
        .families
        .iter()
        .enumerate()
        .map(|(i, fam)| {
            let r = SyntheticRequest {
                family: fam.clone(),
                seed: i as u64,
                arrival: Duration::ZERO,
            };
            let (q, k, v) = r.payload();
            coordinator.submit(fam.clone(), q, k, v)
        })
        .collect();
    for rx in warm_rxs {
        rx.recv().unwrap().outcome.into_result().unwrap();
    }
    println!("  {} families warm in {:.2?}", coordinator.families.len(), t0.elapsed());

    println!("\n== serving 128 requests (Poisson arrivals, zipf family mix) ==");
    let stream = request_stream(&coordinator.families, 128, 12.0, 42);
    let report = run_stream(&coordinator, &stream, 1.0);
    println!(
        "  {} ok / {} errors in {:.2?}",
        report.ok, report.errors, report.wall
    );
    println!(
        "  throughput {:.1} req/s | latency mean {:.2?} p50 {:.2?} p95 {:.2?} | occupancy {:.2}",
        report.throughput_rps, report.mean_latency, report.p50, report.p95, report.mean_occupancy
    );
    println!("  metrics: {}", report.metrics_summary);
    coordinator.shutdown();
}
