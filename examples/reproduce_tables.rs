//! Regenerate every table and figure of the paper's evaluation from the
//! performance model, with the paper's own numbers printed beside each
//! modeled cell.
//!
//! ```sh
//! cargo run --release --example reproduce_tables > tables.txt
//! ```

use qimeng::report::tables;

fn main() {
    println!("{}", tables::table1());
    println!("{}", tables::table2());
    println!("{}", tables::table3());
    // Table 4's time column is measured live from the pipeline.
    let spec = qimeng::sketch::spec::OpSpec::benchmark(
        qimeng::sketch::spec::AttnVariant::Mha,
        1024,
        64,
        false,
    );
    let t0 = std::time::Instant::now();
    let _ = qimeng::pipeline::run(
        &spec,
        &qimeng::perfmodel::gpu::GpuArch::a100(),
        &qimeng::reasoner::profiles::LlmProfile::deepseek_v3(),
        qimeng::pipeline::Target::Pallas,
    )
    .expect("pipeline");
    println!("{}", tables::table4(t0.elapsed().as_secs_f64() * 1e3));
    println!("{}", tables::table5());
    println!("{}", tables::table6());
    println!("{}", tables::table7());
    println!("{}", tables::table8());
    println!("{}", tables::table9());
    println!("{}", tables::figure1());
}
