//! Quickstart: run the paper's two-stage workflow for one operator and
//! watch every intermediate product.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Steps (Figure 3 of the paper): operator spec → TL Sketch (stage 1a)
//! → TL Code (stage 1b: parameters, allocations, reshape, prefetch)
//! → verification (static + numeric vs the reference oracle)
//! → Pallas translation (runnable kernel source).

use qimeng::perfmodel::gpu::GpuArch;
use qimeng::pipeline::{run, Target};
use qimeng::reasoner::profiles::LlmProfile;
use qimeng::sketch::spec::{AttnVariant, OpSpec};
use qimeng::tl::printer::print_program;

fn main() {
    let spec = OpSpec::benchmark(AttnVariant::Mha, 4096, 64, true);
    let arch = GpuArch::a100();
    let profile = LlmProfile::deepseek_v3();

    println!("== operator ==");
    println!(
        "{} | seq {} | heads {}/{} | head-dim {} | causal {}\n",
        spec.variant, spec.seq_len, spec.num_q_heads, spec.num_kv_heads, spec.head_dim,
        spec.causal
    );

    let result = run(&spec, &arch, &profile, Target::Pallas).expect("pipeline failed");

    println!("== stage 1a: TL Sketch ({} statements) ==", result.sketch.stmt_count());
    println!("{}", print_program(&result.sketch));

    println!(
        "== stage 1b: TL Code ({} statements, BM={} BN={}, smem {} B, {} blocks/SM) ==",
        result.reasoned.program.stmt_count(),
        result.reasoned.tiling.bm,
        result.reasoned.tiling.bn,
        result.reasoned.tiling.smem_bytes,
        result.reasoned.tiling.blocks_per_sm,
    );
    println!("{}", print_program(&result.reasoned.program));

    println!(
        "== verification: {} (numeric probe max|diff| = {:.2e}) ==\n",
        if result.verify.passed { "PASS" } else { "FAIL" },
        result.verify.max_abs_diff.unwrap_or(f32::NAN),
    );

    let source = result.source.unwrap();
    println!(
        "== stage 2: Pallas kernel ({} lines) — first 40 ==",
        source.lines().count()
    );
    for line in source.lines().take(40) {
        println!("{line}");
    }
    println!("...\n");
    println!(
        "pipeline wall-clock: {:.2?} (sketch {:.2?} | reason {:.2?} | verify {:.2?} | translate {:.2?})",
        result.timings.total(),
        result.timings.sketch,
        result.timings.reason,
        result.timings.verify,
        result.timings.translate,
    );
    println!("(the paper's Table 4 budget for this step is ~10 minutes with a live LLM)");
}
