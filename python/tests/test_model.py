"""L2 model layer: tiny transformer forward (kernel-backed) vs the
reference-attention forward, plus AOT lowering smoke checks."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.aot import to_hlo_text


def test_transformer_kernel_forward_matches_ref_forward():
    params = model.make_params(
        jax.random.PRNGKey(0), vocab=64, dim=64, heads=2, layers=2
    )
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, 64, size=(2, 64)), jnp.int32
    )
    got = model.transformer_forward(params, tokens, heads=2)
    want = model.transformer_forward_ref(params, tokens, heads=2)
    np.testing.assert_allclose(got, want, atol=5e-4, rtol=5e-4)


def test_transformer_logits_shape_and_finite():
    params = model.make_params(
        jax.random.PRNGKey(1), vocab=128, dim=64, heads=4, layers=1
    )
    tokens = jnp.zeros((1, 32), jnp.int32)
    logits = model.transformer_forward(params, tokens, heads=4)
    assert logits.shape == (1, 32, 128)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_tiny_lm_lowers_to_hlo_text():
    fn = model.tiny_lm_fn(vocab=64, dim=64, heads=2, layers=1)
    tokens = jax.ShapeDtypeStruct((1, 32), jnp.int32)
    text = to_hlo_text(fn.lower(tokens))
    assert "ENTRY" in text
    assert "f32[1,32,64]" in text or "fusion" in text or "dot" in text


def test_attention_op_flash_path():
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    from compile.kernels import ref

    got = model.attention_op(q, k, v, causal=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)
