"""Hand-written flash Pallas kernel vs the jnp reference oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import flash, ref


def make_qkv(b, hq, hk, s, kv, dq, dv, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, hq, s, dq)), dtype)
    k = jnp.asarray(rng.standard_normal((b, hk, kv, dq)), dtype)
    v = jnp.asarray(rng.standard_normal((b, hk, kv, dv)), dtype)
    return q, k, v


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("head_dim", [64, 128])
def test_flash_mha_matches_ref(causal, head_dim):
    q, k, v = make_qkv(2, 4, 4, 256, 256, head_dim, head_dim, seed=1)
    got = flash.flash_attention(q, k, v, causal=causal)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("group", [2, 4, 8])
def test_flash_gqa_groups(group):
    q, k, v = make_qkv(1, 8, 8 // group, 128, 128, 64, 64, seed=2)
    got = flash.flash_attention(q, k, v, causal=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_flash_mqa_single_kv_head():
    q, k, v = make_qkv(2, 8, 1, 128, 128, 64, 64, seed=3)
    got = flash.flash_attention(q, k, v, causal=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("bm,bn", [(32, 32), (64, 32), (32, 64), (128, 64)])
def test_flash_tiling_invariance(bm, bn):
    """Tile sizes must not change the result (same invariant the rust
    interpreter asserts across LLM profiles)."""
    q, k, v = make_qkv(1, 2, 2, 128, 128, 64, 64, seed=4)
    a = flash.flash_attention(q, k, v, causal=True, bm=bm, bn=bn)
    b = flash.flash_attention(q, k, v, causal=True, bm=64, bn=64)
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


def test_flash_asymmetric_dims_mla_shape():
    """MLA-shaped attention: qk over 192, v over 128."""
    q, k, v = make_qkv(1, 4, 4, 128, 128, 192, 128, seed=5)
    got = flash.flash_attention(q, k, v, causal=True, bm=64, bn=64)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_flash_kv_longer_than_q():
    """Decode-style: 64 queries against a 256-token KV cache."""
    q, k, v = make_qkv(1, 2, 2, 64, 256, 64, 64, seed=6)
    got = flash.flash_attention(q, k, v, causal=False, bm=64, bn=64)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_flash_large_scores_no_overflow():
    """Online softmax must be stable for large logits."""
    q = jnp.full((1, 1, 64, 64), 12.0, jnp.float32)
    k = jnp.full((1, 1, 64, 64), 12.0, jnp.float32)
    v = jnp.asarray(np.random.default_rng(7).standard_normal((1, 1, 64, 64)), jnp.float32)
    got = flash.flash_attention(q, k, v, causal=False, bm=32, bn=32)
    assert bool(jnp.all(jnp.isfinite(got)))
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_flash_rows_sum_to_one_through_ones_v():
    q, k, _ = make_qkv(1, 2, 2, 128, 128, 64, 64, seed=8)
    v = jnp.ones((1, 2, 128, 64), jnp.float32)
    got = flash.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(got, jnp.ones_like(got), atol=1e-5)


def test_mla_flash_matches_mla_ref():
    rng = np.random.default_rng(9)
    b, h, s, kv = 1, 4, 128, 128
    nope, rope, latent, vd = 128, 64, 512, 128
    q = jnp.asarray(rng.standard_normal((b, h, s, nope + rope)), jnp.float32)
    c_kv = jnp.asarray(rng.standard_normal((b, kv, latent)) * 0.1, jnp.float32)
    k_rope = jnp.asarray(rng.standard_normal((b, kv, rope)), jnp.float32)
    w_uk = jnp.asarray(rng.standard_normal((h, latent, nope)) * 0.05, jnp.float32)
    w_uv = jnp.asarray(rng.standard_normal((h, latent, vd)) * 0.05, jnp.float32)
    got = flash.mla_flash_attention(q, c_kv, k_rope, w_uk, w_uv, causal=True)
    want = ref.mla_ref(q, c_kv, k_rope, w_uk, w_uv, causal=True)
    np.testing.assert_allclose(got, want, atol=5e-5, rtol=5e-5)
