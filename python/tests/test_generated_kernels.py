"""tlc-generated kernels vs the jnp reference — the end-to-end correctness
claim of the paper's pipeline: code produced from TL by the translation
stage computes exact attention.

Requires `make kernels` (tlc generate-all) to have run; skipped otherwise.
"""

import importlib
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import flash, ref

GEN_DIR = os.path.join(os.path.dirname(__file__), "..", "compile", "kernels", "generated")


def generated_modules():
    if not os.path.isdir(GEN_DIR):
        return []
    return sorted(
        f[:-3]
        for f in os.listdir(GEN_DIR)
        if f.endswith(".py") and not f.startswith("__")
    )


MODULES = generated_modules()

pytestmark = pytest.mark.skipif(
    not MODULES, reason="no generated kernels (run `make kernels` first)"
)


def load(name):
    return importlib.import_module(f"compile.kernels.generated.{name}")


def shapes_for(meta, *, batch=1, seq=256):
    group = meta["group_size"]
    q_heads = max(2, group)
    kv_heads = q_heads // group
    return batch, q_heads, kv_heads, seq


@pytest.mark.parametrize("name", MODULES)
def test_generated_kernel_matches_ref(name):
    mod = load(name)
    meta = mod.META
    b, hq, hk, s = shapes_for(meta)
    rng = np.random.default_rng(hash(name) % 2**32)
    q = jnp.asarray(rng.standard_normal((b, hq, s, meta["qk_dim"])), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hk, s, meta["qk_dim"])), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hk, s, meta["v_dim"])), jnp.float32)
    got = mod.attention(q, k, v, interpret=True)
    want = ref.attention_ref(q, k, v, causal=meta["causal"])
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("name", MODULES)
def test_generated_kernel_matches_expert_flash(name):
    """Generated == hand-written (Table 4's two columns agree numerically)."""
    mod = load(name)
    meta = mod.META
    b, hq, hk, s = shapes_for(meta)
    rng = np.random.default_rng(1 + hash(name) % 2**32)
    q = jnp.asarray(rng.standard_normal((b, hq, s, meta["qk_dim"])), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hk, s, meta["qk_dim"])), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hk, s, meta["v_dim"])), jnp.float32)
    got = mod.attention(q, k, v, interpret=True)
    expert = flash.flash_attention(q, k, v, causal=meta["causal"], bm=64, bn=64)
    np.testing.assert_allclose(got, expert, atol=3e-5, rtol=3e-5)


def test_generated_set_covers_paper_variants():
    """The standard kernel set covers the main-table families."""
    variants = {load(n).META["variant"] for n in MODULES}
    assert {"mha", "gqa", "mqa", "mla"} <= variants
    causal_mha = [
        n for n in MODULES if load(n).META["variant"] == "mha" and load(n).META["causal"]
    ]
    assert causal_mha, "no causal MHA kernel generated"


def test_generated_meta_consistent_with_module_constants():
    for name in MODULES:
        mod = load(name)
        assert mod.BM == mod.META["bm"]
        assert mod.BN == mod.META["bn"]
        assert mod.QK_DIM == mod.META["qk_dim"]
        assert mod.V_DIM == mod.META["v_dim"]
