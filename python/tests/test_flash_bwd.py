"""FlashAttention backward kernels vs jax.grad of the jnp reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import flash_bwd, ref


def grads_ref(q, k, v, do, causal):
    def loss(q, k, v):
        o = ref.attention_ref(q, k, v, causal=causal)
        return jnp.sum(o * do)

    return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)


def make(b, h, s, d, seed):
    rng = np.random.default_rng(seed)
    mk = lambda: jnp.asarray(rng.standard_normal((b, h, s, d)) * 0.5, jnp.float32)
    return mk(), mk(), mk(), mk()


@pytest.mark.parametrize("causal", [False, True])
def test_forward_with_lse_matches_ref(causal):
    q, k, v, _ = make(1, 2, 128, 64, seed=1)
    o, lse = flash_bwd.flash_attention_fwd(q, k, v, causal=causal)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(o, want, atol=2e-5, rtol=2e-5)
    # lse must reproduce the softmax denominator: exp(s - lse) row-sums to 1.
    assert lse.shape == (1, 2, 128, 1)
    assert bool(jnp.all(jnp.isfinite(lse)))


@pytest.mark.parametrize("causal", [False, True])
def test_backward_matches_autodiff(causal):
    q, k, v, do = make(1, 2, 128, 64, seed=2)
    o, lse = flash_bwd.flash_attention_fwd(q, k, v, causal=causal)
    dq, dk, dv = flash_bwd.flash_attention_bwd(q, k, v, o, lse, do, causal=causal)
    rdq, rdk, rdv = grads_ref(q, k, v, do, causal)
    np.testing.assert_allclose(dq, rdq, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(dk, rdk, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(dv, rdv, atol=5e-4, rtol=5e-4)


def test_backward_tiling_invariance():
    q, k, v, do = make(1, 1, 128, 32, seed=3)
    o, lse = flash_bwd.flash_attention_fwd(q, k, v, causal=True, bm=32, bn=32)
    a = flash_bwd.flash_attention_bwd(q, k, v, o, lse, do, causal=True, bm=32, bn=32)
    b = flash_bwd.flash_attention_bwd(q, k, v, o, lse, do, causal=True, bm=64, bn=64)
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, atol=2e-4, rtol=2e-4)


def test_backward_asymmetric_v_dim():
    """MLA-shaped gradients: qk dim 96, v dim 32."""
    rng = np.random.default_rng(4)
    b, h, s = 1, 2, 64
    q = jnp.asarray(rng.standard_normal((b, h, s, 96)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, 96)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, 32)) * 0.5, jnp.float32)
    do = jnp.asarray(rng.standard_normal((b, h, s, 32)) * 0.5, jnp.float32)
    o, lse = flash_bwd.flash_attention_fwd(q, k, v, causal=True)
    dq, dk, dv = flash_bwd.flash_attention_bwd(q, k, v, o, lse, do, causal=True)
    rdq, rdk, rdv = grads_ref(q, k, v, do, True)
    np.testing.assert_allclose(dq, rdq, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(dk, rdk, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(dv, rdv, atol=5e-4, rtol=5e-4)
