"""Self-consistency of the reference oracles."""

import jax.numpy as jnp
import numpy as np

from compile.kernels import ref


def test_softmax_rows_sum_to_one():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 2, 64, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 64, 32)), jnp.float32)
    v = jnp.ones((1, 2, 64, 16), jnp.float32)
    out = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, jnp.ones_like(out), atol=1e-5)


def test_causal_first_position_copies_v0():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 1, 16, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 16, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 1, 16, 8)), jnp.float32)
    out = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out[0, 0, 0], v[0, 0, 0], atol=1e-5)


def test_gqa_broadcast_equals_explicit_repeat():
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((1, 4, 32, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 32, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 32, 16)), jnp.float32)
    got = ref.attention_ref(q, k, v, causal=False)
    k_rep = jnp.repeat(k, 2, axis=1)
    v_rep = jnp.repeat(v, 2, axis=1)
    want = ref.attention_ref(q, k_rep, v_rep, causal=False)
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_mla_decompress_shapes():
    rng = np.random.default_rng(3)
    c_kv = jnp.asarray(rng.standard_normal((2, 32, 64)), jnp.float32)
    k_rope = jnp.asarray(rng.standard_normal((2, 32, 8)), jnp.float32)
    w_uk = jnp.asarray(rng.standard_normal((4, 64, 16)), jnp.float32)
    w_uv = jnp.asarray(rng.standard_normal((4, 64, 16)), jnp.float32)
    k, v = ref.mla_decompress(c_kv, k_rope, w_uk, w_uv)
    assert k.shape == (2, 4, 32, 24)  # nope 16 + rope 8
    assert v.shape == (2, 4, 32, 16)


def test_scale_defaults_to_rsqrt_head_dim():
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.standard_normal((1, 1, 16, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 16, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 1, 16, 64)), jnp.float32)
    a = ref.attention_ref(q, k, v)
    b = ref.attention_ref(q, k, v, scale=1.0 / 8.0)
    np.testing.assert_allclose(a, b, atol=1e-6)
