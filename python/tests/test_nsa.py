"""Simplified NSA: blocked implementation vs the dense oracle (Table 9's
two rows must agree numerically; the latency ratio is the perf model's
job)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import nsa, ref


def make(b, h, s, d, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("topk", [2, 4])
def test_nsa_blocked_matches_ref(topk):
    q, k, v = make(1, 2, 256, 64, seed=1)
    got = nsa.nsa_blocked(q, k, v, block=32, topk=topk, window=64)
    want = ref.nsa_ref(q, k, v, block=32, topk=topk, window=64)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_nsa_full_window_reduces_to_causal_attention():
    """With window >= kv, the window branch equals dense causal attention."""
    q, k, v = make(1, 2, 128, 64, seed=2)
    o_cmp, o_sel, o_win = ref.nsa_branches(q, k, v, block=32, topk=2, window=128)
    dense = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(o_win, dense, atol=2e-5, rtol=2e-5)


def test_nsa_selection_subset_of_causal():
    """Selection-branch rows are convex combinations of visible V rows:
    with V == ones, outputs are exactly one."""
    q, k, _ = make(1, 1, 128, 64, seed=3)
    v = jnp.ones((1, 1, 128, 64), jnp.float32)
    out = ref.nsa_ref(q, k, v, block=32, topk=2, window=32)
    np.testing.assert_allclose(out, jnp.ones_like(out), atol=1e-5)


def test_nsa_outputs_finite():
    q, k, v = make(2, 2, 256, 64, seed=4)
    out = nsa.nsa_blocked(q, k, v, block=64, topk=2, window=128)
    assert bool(jnp.all(jnp.isfinite(out)))
