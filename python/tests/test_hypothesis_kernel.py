"""Hypothesis sweep: the flash Pallas kernel must match ref.py across
randomly drawn shapes, tilings, dtypes and mask settings."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import flash, ref

# CPU interpret-mode is slow; keep the per-case problem small but let
# hypothesis explore the shape space broadly.
SETTINGS = settings(max_examples=25, deadline=None)


@st.composite
def attention_cases(draw):
    head_dim = draw(st.sampled_from([32, 64, 128]))
    v_dim = draw(st.sampled_from([head_dim, 64]))
    group = draw(st.sampled_from([1, 2, 4]))
    kv_heads = draw(st.sampled_from([1, 2]))
    blocks_q = draw(st.integers(1, 3))
    blocks_k = draw(st.integers(1, 3))
    bm = draw(st.sampled_from([16, 32, 64]))
    bn = draw(st.sampled_from([16, 32, 64]))
    causal = draw(st.booleans())
    dtype = draw(st.sampled_from([jnp.float32, jnp.bfloat16]))
    seed = draw(st.integers(0, 2**31 - 1))
    return dict(
        b=draw(st.integers(1, 2)),
        hq=kv_heads * group,
        hk=kv_heads,
        s=bm * blocks_q,
        kv=bn * blocks_k,
        dq=head_dim,
        dv=v_dim,
        bm=bm,
        bn=bn,
        causal=causal,
        dtype=dtype,
        seed=seed,
    )


@SETTINGS
@given(attention_cases())
def test_flash_matches_ref_random_cases(case):
    if case["causal"]:
        # Causal assumes prefix-aligned query/key positions (q i <-> key i),
        # which requires kv == seq — the paper's benchmark setting. kv < s
        # would leave fully-masked rows; kv > s changes alignment semantics.
        case["kv"] = case["s"]
    rng = np.random.default_rng(case["seed"])
    q = jnp.asarray(
        rng.standard_normal((case["b"], case["hq"], case["s"], case["dq"])),
        case["dtype"],
    )
    k = jnp.asarray(
        rng.standard_normal((case["b"], case["hk"], case["kv"], case["dq"])),
        case["dtype"],
    )
    v = jnp.asarray(
        rng.standard_normal((case["b"], case["hk"], case["kv"], case["dv"])),
        case["dtype"],
    )
    got = flash.flash_attention(
        q, k, v, causal=case["causal"], bm=case["bm"], bn=case["bn"]
    )
    want = ref.attention_ref(q, k, v, causal=case["causal"])
    tol = 2e-5 if case["dtype"] == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )
