"""L2 structural perf checks on the lowered HLO artifacts (DESIGN.md §7):
the flash loop must lower to a single fused while-loop per kernel (one
pass over KV — no S materialization round-trips), with no duplicated
GEMMs. These run on the AOT artifacts; skipped until `make artifacts`.
"""

import os
import re

import pytest

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def artifact_files():
    if not os.path.isdir(ART_DIR):
        return []
    return sorted(
        f
        for f in os.listdir(ART_DIR)
        if f.endswith(".hlo.txt") and not f.startswith("tiny_lm")
    )


FILES = artifact_files()

pytestmark = pytest.mark.skipif(not FILES, reason="run `make artifacts` first")


def read(fname):
    with open(os.path.join(ART_DIR, fname)) as f:
        return f.read()


@pytest.mark.parametrize("fname", FILES)
def test_single_fused_kv_loop(fname):
    """Exactly two while loops per attention artifact: pallas
    interpret-mode emulates the grid with an outer while loop, and the
    fused online-softmax KV sweep is the inner one. Any further loop
    would mean the fusion was broken (e.g. a separate softmax pass)."""
    text = read(fname)
    whiles = len(re.findall(r"\bwhile\(", text))
    assert whiles == 2, f"{fname}: expected grid + kv loops, found {whiles}"


@pytest.mark.parametrize("fname", FILES)
def test_two_gemms_per_loop_body_no_recompute(fname):
    """The loop body contains exactly the two attention GEMMs (QK^T and
    PV) — duplicated dots would indicate recomputation."""
    text = read(fname)
    # Find the while-body computation: jax lowers it as a computation
    # containing the dots.
    dots = len(re.findall(r"\bdot\(", text))
    # 2 GEMMs in the body; allow a small number of extra dots from
    # epilogue/casting fusions but flag clear duplication.
    assert 2 <= dots <= 4, f"{fname}: {dots} dot ops (expected 2-4)"


@pytest.mark.parametrize("fname", FILES)
def test_no_full_score_matrix_in_hbm(fname):
    """No (seq, kv)-shaped f32 buffer may appear as a loop-carried or
    output value: the score matrix must stay tile-sized (the whole point
    of the fused kernel). Tile shapes are (BM<=128, BN<=64ish); a full
    256x256 f32 score buffer would betray an unfused lowering."""
    text = read(fname)
    assert "f32[256,256]" not in text.replace(" ", ""), (
        f"{fname}: full score matrix materialized"
    )


def test_exp_fused_into_loop():
    """The exponential (softmax) must appear inside the module exactly
    where the loop body computes it — at least one artifact sanity check
    that the online softmax lowered to `exponential` ops."""
    text = read(FILES[0])
    assert "exponential" in text, "no exponential op — softmax missing?"
