"""Hand-written FlashAttention Pallas kernel — the "human expert"
baseline of the paper's Table 4.

Functionally equivalent to what `tlc generate` emits; written the way a
kernel engineer would (parametrized over tile sizes, variants and causal
masking) to stand in for the months-of-effort expert implementation the
paper compares development cost against. The generated kernels must match
this one (and both must match ref.py) — pytest enforces all three-way
agreements.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): VMEM tiles instead
of CUDA shared memory, MXU `jnp.dot` instead of Tensor-Core mma, BlockSpec
instead of the threadblock schedule. interpret=True everywhere — the CPU
PJRT plugin cannot execute Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MASK_VALUE = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bm, bn, causal):
    """One (batch, q-head, q-block) program instance."""
    block_idx = pl.program_id(2)
    kv_len = k_ref.shape[2]
    v_dim = v_ref.shape[3]

    q = q_ref[0, 0].astype(jnp.float32)
    scale = 1.0 / (q.shape[-1] ** 0.5)

    acc = jnp.zeros((bm, v_dim), jnp.float32)
    m_i = jnp.zeros((bm, 1), jnp.float32)
    l_i = jnp.zeros((bm, 1), jnp.float32)

    def body(i, carry):
        acc, m_i, l_i = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0, 0], i * bn, bn, axis=0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0, 0], i * bn, bn, axis=0)
        s = jnp.dot(q, k.astype(jnp.float32).T, preferred_element_type=jnp.float32)
        s = s * scale
        if causal:
            q_pos = block_idx * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
            k_pos = i * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
            s = jnp.where(k_pos <= q_pos, s, MASK_VALUE)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=1, keepdims=True))
        corr = jnp.exp(m_i - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_i * corr + jnp.sum(p, axis=1, keepdims=True)
        acc = acc * corr + jnp.dot(
            p, v.astype(jnp.float32), preferred_element_type=jnp.float32
        )
        return acc, m_new, l_new

    if causal:
        num_blocks = ((block_idx + 1) * bm + bn - 1) // bn
    else:
        num_blocks = kv_len // bn
    acc, m_i, l_i = jax.lax.fori_loop(0, num_blocks, body, (acc, m_i, l_i))
    o_ref[0, 0] = (acc / l_i).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=False, bm=128, bn=64, interpret=True):
    """FlashAttention over batched multi-head inputs.

    Args:
        q: (batch, q_heads, seq, qk_dim)
        k: (batch, kv_heads, kv, qk_dim)
        v: (batch, kv_heads, kv, v_dim) — kv_heads divides q_heads
           (GQA/MQA use the same kernel through the BlockSpec index map).
    """
    batch, q_heads, seq, qk_dim = q.shape
    if causal:
        # Causal masking is prefix-aligned (query i attends keys <= i),
        # the paper's benchmark setting; it requires kv == seq.
        assert k.shape[2] == seq, (k.shape[2], seq)
    kv_heads, kv_len = k.shape[1], k.shape[2]
    v_dim = v.shape[3]
    assert q_heads % kv_heads == 0, (q_heads, kv_heads)
    group = q_heads // kv_heads
    bm = min(bm, seq)
    bn = min(bn, kv_len)
    assert seq % bm == 0 and kv_len % bn == 0, (seq, bm, kv_len, bn)

    kernel = functools.partial(_flash_kernel, bm=bm, bn=bn, causal=causal)
    grid = (batch, q_heads, seq // bm)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bm, qk_dim), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, kv_len, qk_dim), lambda b, h, i: (b, h // group, 0, 0)),
            pl.BlockSpec((1, 1, kv_len, v_dim), lambda b, h, i: (b, h // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bm, v_dim), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, q_heads, seq, v_dim), q.dtype),
        interpret=interpret,
    )(q, k, v)


def mla_flash_attention(q, c_kv, k_rope, w_uk, w_uv, *, causal=True, interpret=True):
    """MLA forward: decompress the latent KV cache, then run the flash
    kernel with asymmetric head dims (qk = nope+rope, v = v_dim).

    The decompression is L2 (jax) work that fuses into the same lowered
    module; the kernel itself is dimension-agnostic.
    """
    from . import ref

    k, v = ref.mla_decompress(c_kv, k_rope, w_uk, w_uv)
    return flash_attention(q, k, v, causal=causal, bm=64, bn=64, interpret=interpret)
