"""AUTO-GENERATED kernel package (tlc generate-all). DO NOT EDIT."""
from . import mha_hd64_full_f16  # noqa: F401
from . import mha_hd64_causal_f16  # noqa: F401
from . import mha_hd128_full_f16  # noqa: F401
from . import mha_hd128_causal_f16  # noqa: F401
from . import gqa_hd64_full_f16  # noqa: F401
from . import gqa_hd64_causal_f16  # noqa: F401
from . import gqa_hd128_full_f16  # noqa: F401
from . import gqa_hd128_causal_f16  # noqa: F401
from . import mqa_hd64_full_f16  # noqa: F401
from . import mqa_hd64_causal_f16  # noqa: F401
from . import mqa_hd128_full_f16  # noqa: F401
from . import mqa_hd128_causal_f16  # noqa: F401
from . import mla_hd128_causal_f16  # noqa: F401
