"""Blocked (FlashAttention-style) implementation of simplified NSA.

Table 9 of the paper compares a naive NSA against the generated blocked
version. ``nsa_blocked`` is the generated-equivalent: the three branches
run as blocked online-softmax passes reusing the flash kernel for the
window/compression branches, with the selection branch gathering whole KV
blocks before a dense (but small) attention. The dense oracle is
``ref.nsa_ref``.
"""

import jax
import jax.numpy as jnp

from . import ref
from .flash import flash_attention


def nsa_blocked(q, k, v, *, block=64, topk=16, window=512, interpret=True):
    """Blocked simplified-NSA forward.

    Same math as ref.nsa_ref (equal-gated cmp/sel/win branches), with the
    branch computations structured the way the generated kernel executes
    them: pooled-KV flash pass, per-query-block gather + small dense
    attention, and a windowed flash pass.
    """
    b, h, s, d = q.shape
    kv = k.shape[2]
    nblk = kv // block

    # --- compression branch (small flash pass over pooled KV) ---
    k_cmp = k[:, :, : nblk * block].reshape(b, h, nblk, block, d).mean(axis=3)
    v_cmp = v[:, :, : nblk * block].reshape(b, h, nblk, block, d).mean(axis=3)
    scale = 1.0 / (d ** 0.5)
    s_cmp = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k_cmp) * scale
    pos_q = jnp.arange(s)[:, None]
    blk_end = (jnp.arange(nblk) + 1) * block - 1
    cmp_mask = blk_end[None, :] <= pos_q
    s_cmp_masked = jnp.where(cmp_mask[None, None], s_cmp, ref.MASK_VALUE)
    p_cmp = jax.nn.softmax(s_cmp_masked, axis=-1)
    o_cmp = jnp.einsum("bhqk,bhkd->bhqd", p_cmp, v_cmp)

    # --- selection branch ---
    # Per query: top-k blocks by compression score, then attention over
    # the gathered blocks only (the blocked kernel's indirect Copy).
    kk = min(topk, nblk)
    top_blocks = jnp.argsort(s_cmp_masked, axis=-1)[..., ::-1][..., :kk]
    sel_mask = jnp.any(jax.nn.one_hot(top_blocks, nblk, dtype=bool), axis=-2)
    tok_sel = jnp.repeat(sel_mask, block, axis=-1)
    if tok_sel.shape[-1] < kv:
        pad = jnp.zeros((*tok_sel.shape[:-1], kv - tok_sel.shape[-1]), bool)
        tok_sel = jnp.concatenate([tok_sel, pad], axis=-1)
    pos_k = jnp.arange(kv)[None, :]
    causal = pos_k <= pos_q
    s_full = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    s_sel = jnp.where(tok_sel & causal[None, None], s_full, ref.MASK_VALUE)
    p_sel = jax.nn.softmax(s_sel, axis=-1)
    o_sel = jnp.einsum("bhqk,bhkd->bhqd", p_sel, v.astype(jnp.float32))

    # --- sliding-window branch (flash kernel when the window covers the
    # whole sequence, masked flash otherwise) ---
    if window >= kv:
        o_win = flash_attention(q, k, v, causal=True, interpret=interpret).astype(
            jnp.float32
        )
    else:
        win_mask = (pos_q - pos_k < window) & causal
        s_win = jnp.where(win_mask[None, None], s_full, ref.MASK_VALUE)
        p_win = jax.nn.softmax(s_win, axis=-1)
        o_win = jnp.einsum("bhqk,bhkd->bhqd", p_win, v.astype(jnp.float32))

    return (o_cmp + o_sel + o_win) / 3.0
