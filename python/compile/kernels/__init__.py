"""L1 — Pallas kernels (build-time only; never imported at runtime).

* ``ref``       pure-jnp oracles for every variant (also the vanilla-LLM
                torch-style baseline of the paper's tables)
* ``flash``     hand-written FlashAttention kernel ("human expert",
                Table 4 baseline)
* ``nsa``       blocked simplified Native Sparse Attention (Table 9)
* ``generated`` kernels emitted by ``tlc generate-all`` (the paper's
                pipeline output) — created by ``make kernels``
"""

from . import flash, nsa, ref  # noqa: F401
