"""Pure-jnp reference oracles for every attention variant.

These are the ground truth the Pallas kernels (hand-written *and*
tlc-generated) are validated against at build time — the pytest half of
the paper's correctness story. Everything here materializes the full
(S, K) score matrix, i.e. it is also the "vanilla LLM" torch-style
baseline of the paper's tables (the one that OOMs at long context).
"""

import jax
import jax.numpy as jnp

# Finite stand-in for -inf; must match rust verify::tensor::MASK_VALUE and
# the generated kernels' MASK_VALUE so all three layers agree on masked
# softmax behaviour.
MASK_VALUE = -1e30


def attention_ref(q, k, v, *, causal=False, scale=None):
    """Reference attention with GQA/MQA head broadcasting.

    Args:
        q: (batch, q_heads, seq, qk_dim)
        k: (batch, kv_heads, kv, qk_dim) — kv_heads must divide q_heads
        v: (batch, kv_heads, kv, v_dim)
        causal: apply a causal mask (query i attends keys <= i).
        scale: softmax scale; default 1/sqrt(qk_dim).

    Returns:
        (batch, q_heads, seq, v_dim) in float32.
    """
    b, hq, s, d = q.shape
    hk = k.shape[1]
    assert hq % hk == 0, f"q_heads {hq} not a multiple of kv_heads {hk}"
    group = hq // hk
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    q = q.astype(jnp.float32)
    k = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    v = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    s_mat = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        kv = k.shape[2]
        mask = jnp.tril(jnp.ones((s, kv), dtype=bool), k=kv - s)
        s_mat = jnp.where(mask, s_mat, MASK_VALUE)
    p = jax.nn.softmax(s_mat, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def mla_decompress(c_kv, k_rope, w_uk, w_uv):
    """DeepSeek-style MLA decompression (Table 2 setup).

    The KV cache stores a per-token latent ``c_kv`` (latent_dim) plus a
    shared rope key ``k_rope`` (rope_dim). Per-head K/V are reconstructed
    with the up-projection matrices.

    Args:
        c_kv:   (batch, kv, latent_dim)
        k_rope: (batch, kv, rope_dim) — shared across heads
        w_uk:   (heads, latent_dim, nope_dim)
        w_uv:   (heads, latent_dim, v_dim)

    Returns:
        k: (batch, heads, kv, nope_dim + rope_dim), v: (batch, heads, kv, v_dim)
    """
    k_nope = jnp.einsum("bkl,hld->bhkd", c_kv, w_uk)
    v = jnp.einsum("bkl,hld->bhkd", c_kv, w_uv)
    h = w_uk.shape[0]
    k_rope_b = jnp.broadcast_to(
        k_rope[:, None, :, :], (k_rope.shape[0], h, k_rope.shape[1], k_rope.shape[2])
    )
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    return k, v


def mla_ref(q, c_kv, k_rope, w_uk, w_uv, *, causal=True):
    """Reference MLA: decompress, then standard attention with asymmetric
    dims (qk over nope+rope, v over v_dim). q: (b, h, s, nope+rope)."""
    k, v = mla_decompress(c_kv, k_rope, w_uk, w_uv)
    return attention_ref(q, k, v, causal=causal)


def nsa_branches(q, k, v, *, block=64, topk=16, window=512):
    """Simplified Native Sparse Attention (Appendix A, Table 9), dense
    reference. Returns the three branch outputs (cmp, sel, win).

    Branches over the causal KV stream:
      * compression: attention over mean-pooled KV blocks;
      * selection: attention restricted to the top-k blocks ranked by the
        compression scores (per query);
      * sliding window: attention over the last `window` positions.
    """
    b, h, s, d = q.shape
    kv = k.shape[2]
    scale = 1.0 / (d ** 0.5)
    q32, k32, v32 = (x.astype(jnp.float32) for x in (q, k, v))

    pos_q = jnp.arange(s)[:, None]
    pos_k = jnp.arange(kv)[None, :]
    causal = pos_k <= pos_q

    # --- compression branch: mean-pool non-overlapping blocks ---
    nblk = kv // block
    k_cmp = k32[:, :, : nblk * block].reshape(b, h, nblk, block, d).mean(axis=3)
    v_cmp = v32[:, :, : nblk * block].reshape(b, h, nblk, block, d).mean(axis=3)
    s_cmp = jnp.einsum("bhqd,bhkd->bhqk", q32, k_cmp) * scale
    blk_end = (jnp.arange(nblk) + 1) * block - 1
    cmp_mask = blk_end[None, :] <= pos_q  # block fully visible to query
    s_cmp = jnp.where(cmp_mask[None, None], s_cmp, MASK_VALUE)
    p_cmp = jax.nn.softmax(s_cmp, axis=-1)
    o_cmp = jnp.einsum("bhqk,bhkd->bhqd", p_cmp, v_cmp)

    # --- selection branch: top-k blocks by compression score ---
    kk = min(topk, nblk)
    top_blocks = jnp.argsort(s_cmp, axis=-1)[..., ::-1][..., :kk]
    sel_mask = jnp.any(jax.nn.one_hot(top_blocks, nblk, dtype=bool), axis=-2)
    tok_sel = jnp.repeat(sel_mask, block, axis=-1)
    if tok_sel.shape[-1] < kv:  # ragged tail beyond pooled blocks
        pad = jnp.zeros((*tok_sel.shape[:-1], kv - tok_sel.shape[-1]), bool)
        tok_sel = jnp.concatenate([tok_sel, pad], axis=-1)
    s_full = jnp.einsum("bhqd,bhkd->bhqk", q32, k32) * scale
    s_sel = jnp.where(tok_sel & causal[None, None], s_full, MASK_VALUE)
    p_sel = jax.nn.softmax(s_sel, axis=-1)
    o_sel = jnp.einsum("bhqk,bhkd->bhqd", p_sel, v32)

    # --- sliding-window branch ---
    win_mask = (pos_q - pos_k < window) & causal
    s_win = jnp.where(win_mask[None, None], s_full, MASK_VALUE)
    p_win = jax.nn.softmax(s_win, axis=-1)
    o_win = jnp.einsum("bhqk,bhkd->bhqd", p_win, v32)
    return o_cmp, o_sel, o_win


def nsa_ref(q, k, v, *, block=64, topk=16, window=512):
    """NSA with equal branch gates (the NSA paper learns the gate; a fixed
    gate preserves the compute/data-movement structure Table 9 measures)."""
    o_cmp, o_sel, o_win = nsa_branches(q, k, v, block=block, topk=topk, window=window)
    return (o_cmp + o_sel + o_win) / 3.0
