"""FlashAttention backward pass (extension beyond the paper).

The paper generates forward operators only and names "a broader range of
complex operators" as future work; the backward pass is the natural next
operator, and its TL description uses the same Copy/Compute vocabulary
(two extra fused GEMMs per tile plus the ds = p * (dp - D) rescale).

Implementation follows Dao et al. (2022): the forward saves the row
log-sum-exp; backward recomputes P tile-by-tile instead of storing it.
Two kernels, both online over the opposite axis:

  * dq kernel: one program per (b, h, q-block), sweeping KV tiles;
  * dkv kernel: one program per (b, h, kv-block), sweeping Q tiles.

Validated against jax.grad of the jnp reference in
tests/test_flash_bwd.py. interpret=True (CPU PJRT) as everywhere.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MASK_VALUE = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, bm, bn, causal):
    """Forward with saved row log-sum-exp (scale folded in)."""
    block_idx = pl.program_id(2)
    kv_len = k_ref.shape[2]
    v_dim = v_ref.shape[3]
    q = q_ref[0, 0].astype(jnp.float32)
    scale = 1.0 / (q.shape[-1] ** 0.5)

    acc = jnp.zeros((bm, v_dim), jnp.float32)
    m_i = jnp.full((bm, 1), -jnp.inf, jnp.float32)
    l_i = jnp.zeros((bm, 1), jnp.float32)

    def body(i, carry):
        acc, m_i, l_i = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0, 0], i * bn, bn, axis=0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0, 0], i * bn, bn, axis=0)
        s = jnp.dot(q, k.astype(jnp.float32).T, preferred_element_type=jnp.float32)
        s = s * scale
        if causal:
            q_pos = block_idx * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
            k_pos = i * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
            s = jnp.where(k_pos <= q_pos, s, MASK_VALUE)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=1, keepdims=True))
        corr = jnp.exp(m_i - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_i * corr + jnp.sum(p, axis=1, keepdims=True)
        acc = acc * corr + jnp.dot(
            p, v.astype(jnp.float32), preferred_element_type=jnp.float32
        )
        return acc, m_new, l_new

    num_blocks = ((block_idx + 1) * bm + bn - 1) // bn if causal else kv_len // bn
    acc, m_i, l_i = jax.lax.fori_loop(0, num_blocks, body, (acc, m_i, l_i))
    o_ref[0, 0] = (acc / l_i).astype(o_ref.dtype)
    lse_ref[0, 0] = (m_i + jnp.log(l_i)).astype(lse_ref.dtype)


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, *, bm, bn, causal):
    block_idx = pl.program_id(2)
    kv_len = k_ref.shape[2]
    q = q_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].astype(jnp.float32)
    delta = delta_ref[0, 0].astype(jnp.float32)
    scale = 1.0 / (q.shape[-1] ** 0.5)

    dq = jnp.zeros_like(q)

    def body(i, dq):
        k = jax.lax.dynamic_slice_in_dim(k_ref[0, 0], i * bn, bn, axis=0).astype(jnp.float32)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0, 0], i * bn, bn, axis=0).astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = block_idx * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
            k_pos = i * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
            s = jnp.where(k_pos <= q_pos, s, MASK_VALUE)
        p = jnp.exp(s - lse)  # recomputed softmax via saved lse
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta)
        return dq + jnp.dot(ds, k, preferred_element_type=jnp.float32) * scale

    num_blocks = ((block_idx + 1) * bm + bn - 1) // bn if causal else kv_len // bn
    dq = jax.lax.fori_loop(0, num_blocks, body, dq)
    dq_ref[0, 0] = dq.astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref, *, bm, bn, causal):
    kv_block = pl.program_id(2)
    seq_len = q_ref.shape[2]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    d = k.shape[-1]
    scale = 1.0 / (d ** 0.5)

    dk = jnp.zeros_like(k)
    dv = jnp.zeros_like(v)

    def body(j, carry):
        dk, dv = carry
        q = jax.lax.dynamic_slice_in_dim(q_ref[0, 0], j * bm, bm, axis=0).astype(jnp.float32)
        do = jax.lax.dynamic_slice_in_dim(do_ref[0, 0], j * bm, bm, axis=0).astype(jnp.float32)
        lse = jax.lax.dynamic_slice_in_dim(lse_ref[0, 0], j * bm, bm, axis=0).astype(jnp.float32)
        delta = jax.lax.dynamic_slice_in_dim(delta_ref[0, 0], j * bm, bm, axis=0).astype(
            jnp.float32
        )
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = j * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, k.shape[0]), 0)
            k_pos = kv_block * k.shape[0] + jax.lax.broadcasted_iota(
                jnp.int32, (bm, k.shape[0]), 1
            )
            s = jnp.where(k_pos <= q_pos, s, MASK_VALUE)
        p = jnp.exp(s - lse)
        dv = dv + jnp.dot(p.T, do, preferred_element_type=jnp.float32)
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta)
        dk = dk + jnp.dot(ds.T, q, preferred_element_type=jnp.float32) * scale
        return dk, dv

    if causal:
        # q-blocks before this kv-block are fully masked: start there.
        start = (kv_block * k.shape[0]) // bm
    else:
        start = 0
    dk, dv = jax.lax.fori_loop(start, seq_len // bm, body, (dk, dv))
    dk_ref[0, 0] = dk.astype(dk_ref.dtype)
    dv_ref[0, 0] = dv.astype(dv_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal=False, bm=64, bn=64, interpret=True):
    """Forward returning (o, lse); lse: (batch, heads, seq, 1)."""
    batch, heads, seq, d = q.shape
    kv_len = k.shape[2]
    v_dim = v.shape[3]
    assert k.shape[1] == heads, "backward path requires MHA layout (repeat KV first)"
    bm = min(bm, seq)
    bn = min(bn, kv_len)
    kernel = functools.partial(_fwd_kernel, bm=bm, bn=bn, causal=causal)
    grid = (batch, heads, seq // bm)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bm, d), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, kv_len, d), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, kv_len, v_dim), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bm, v_dim), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bm, 1), lambda b, h, i: (b, h, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, heads, seq, v_dim), q.dtype),
            jax.ShapeDtypeStruct((batch, heads, seq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def flash_attention_bwd(q, k, v, o, lse, do, *, causal=False, bm=64, bn=64, interpret=True):
    """Backward: returns (dq, dk, dv). Recomputation strategy with the
    saved lse; delta = rowsum(do * o) computed at L2."""
    batch, heads, seq, d = q.shape
    kv_len = k.shape[2]
    v_dim = v.shape[3]
    bm = min(bm, seq)
    bn = min(bn, kv_len)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1, keepdims=True)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, bm=bm, bn=bn, causal=causal),
        grid=(batch, heads, seq // bm),
        in_specs=[
            pl.BlockSpec((1, 1, bm, d), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, kv_len, d), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, kv_len, v_dim), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bm, v_dim), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bm, 1), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bm, 1), lambda b, h, i: (b, h, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bm, d), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, bm=bm, bn=bn, causal=causal),
        grid=(batch, heads, kv_len // bn),
        in_specs=[
            pl.BlockSpec((1, 1, seq, d), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bn, d), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bn, v_dim), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, seq, v_dim), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, seq, 1), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, seq, 1), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bn, d), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bn, v_dim), lambda b, h, i: (b, h, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(k.shape, k.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
