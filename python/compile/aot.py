"""AOT lowering: jax functions (with Pallas kernels inside) → HLO text
artifacts consumed by the rust PJRT runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the `xla` crate) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage (from python/):  python -m compile.aot [--out-dir ../artifacts]
Artifacts:
  <kernel>__b<B>_h<H>kv<HK>_s<S>.hlo.txt     one per (kernel family, shape)
  tiny_lm__v<V>_d<D>_h<H>_l<L>_b<B>_s<S>.hlo.txt
  manifest.txt                                key=value lines, rust-parseable
"""

import argparse
import importlib
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _attention_shapes(meta):
    """Serving shapes per kernel family (CPU-sized; the perf model covers
    paper-scale shapes). Two batch sizes per family so the coordinator's
    dynamic batcher has real capacity choices. q_heads must be a multiple
    of the kernel's compiled GROUP_SIZE."""
    group = meta["group_size"]
    q_heads = max(4, group)
    kv_heads = q_heads // group
    seq = 256
    return [
        dict(batch=1, q_heads=q_heads, kv_heads=kv_heads, seq=seq, kv=seq),
        dict(batch=4, q_heads=q_heads, kv_heads=kv_heads, seq=seq, kv=seq),
    ]


def lower_attention_kernel(mod_name, out_dir):
    """Lower one generated kernel module to an HLO artifact. Returns the
    manifest line."""
    mod = importlib.import_module(f"compile.kernels.generated.{mod_name}")
    meta = mod.META
    qk, vd = meta["qk_dim"], meta["v_dim"]

    def fn(q, k, v):
        return (mod.attention(q, k, v, interpret=True),)

    lines = []
    for sh in _attention_shapes(meta):
        q = jax.ShapeDtypeStruct((sh["batch"], sh["q_heads"], sh["seq"], qk), jnp.float32)
        k = jax.ShapeDtypeStruct((sh["batch"], sh["kv_heads"], sh["kv"], qk), jnp.float32)
        v = jax.ShapeDtypeStruct((sh["batch"], sh["kv_heads"], sh["kv"], vd), jnp.float32)
        lowered = jax.jit(fn).lower(q, k, v)
        text = to_hlo_text(lowered)

        art_id = (
            f"{mod_name}__b{sh['batch']}_h{sh['q_heads']}kv{sh['kv_heads']}_s{sh['seq']}"
        )
        fname = f"{art_id}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        lines.append(
            f"artifact {art_id} file={fname} kind=attention kernel={mod_name} "
            f"variant={meta['variant']} causal={int(meta['causal'])} "
            f"batch={sh['batch']} q_heads={sh['q_heads']} kv_heads={sh['kv_heads']} "
            f"seq={sh['seq']} kv={sh['kv']} qk={qk} vd={vd}"
        )
    return lines


def lower_tiny_lm(out_dir, *, vocab=512, dim=128, heads=4, layers=2, batch=4, seq=128):
    """Lower the tiny transformer LM (weights burned in as constants)."""
    fn = model.tiny_lm_fn(vocab=vocab, dim=dim, heads=heads, layers=layers)
    tokens = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    lowered = fn.lower(tokens)
    text = to_hlo_text(lowered)
    art_id = f"tiny_lm__v{vocab}_d{dim}_h{heads}_l{layers}_b{batch}_s{seq}"
    fname = f"{art_id}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    return (
        f"artifact {art_id} file={fname} kind=lm vocab={vocab} dim={dim} "
        f"heads={heads} layers={layers} batch={batch} seq={seq}"
    )


def discover_generated():
    """Names of tlc-generated kernel modules."""
    gen_dir = os.path.join(os.path.dirname(__file__), "kernels", "generated")
    names = []
    if os.path.isdir(gen_dir):
        for f in sorted(os.listdir(gen_dir)):
            if f.endswith(".py") and not f.startswith("__"):
                names.append(f[: -len(".py")])
    return names


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    ap.add_argument("--skip-lm", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    kernels = discover_generated()
    if not kernels:
        print(
            "no generated kernels found — run `cargo run --release --bin tlc -- "
            "generate-all` (or `make kernels`) first",
            file=sys.stderr,
        )
        sys.exit(1)

    manifest = []
    for name in kernels:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        manifest.extend(lower_attention_kernel(name, args.out_dir))
        print(f"lowered {name} in {time.time() - t0:.1f}s")

    if not args.skip_lm and (not args.only or "tiny_lm" in args.only):
        t0 = time.time()
        manifest.append(lower_tiny_lm(args.out_dir))
        print(f"lowered tiny_lm in {time.time() - t0:.1f}s")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("# AOT artifact manifest — parsed by rust/src/runtime/registry.rs\n")
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest)} artifacts + manifest to {args.out_dir}")


if __name__ == "__main__":
    main()
