"""L2 — jax model layer: attention ops and a tiny transformer block.

Build-time only. ``aot.py`` lowers these functions (with the Pallas
kernels inside) to HLO text; the rust runtime loads and executes the
artifacts — Python never sits on the request path.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import flash, ref


def attention_op(q, k, v, *, causal, bm=128, bn=64, use_generated=None):
    """The servable attention op: generated kernel when available,
    hand-written flash otherwise.

    ``use_generated`` names a module in kernels.generated (e.g.
    "mha_hd64_causal_f16"); its META must match (causal, dims).
    """
    if use_generated is not None:
        import importlib

        mod = importlib.import_module(f"compile.kernels.generated.{use_generated}")
        assert mod.META["causal"] == causal, (
            f"kernel {use_generated} causal={mod.META['causal']} != {causal}"
        )
        return mod.attention(q, k, v, interpret=True)
    return flash.flash_attention(q, k, v, causal=causal, bm=bm, bn=bn, interpret=True)


# ---------------------------------------------------------------------------
# Tiny decoder-only transformer used by the end-to-end serving example: the
# attention inside is the generated/flash kernel, everything else is plain
# jax. Weights are created deterministically (seeded) at AOT time and burned
# into the artifact as constants — the serving path only feeds token ids.
# ---------------------------------------------------------------------------


def make_params(key, *, vocab, dim, heads, layers, mlp_ratio=4):
    """Deterministic tiny-LM parameters."""
    keys = jax.random.split(key, layers * 6 + 2)
    scale = dim ** -0.5
    params = {
        "embed": jax.random.normal(keys[0], (vocab, dim), jnp.float32) * scale,
        "layers": [],
        "out_norm": jnp.ones((dim,), jnp.float32),
    }
    for i in range(layers):
        k0 = keys[2 + i * 6 : 2 + (i + 1) * 6]
        params["layers"].append(
            {
                "wq": jax.random.normal(k0[0], (dim, dim), jnp.float32) * scale,
                "wk": jax.random.normal(k0[1], (dim, dim), jnp.float32) * scale,
                "wv": jax.random.normal(k0[2], (dim, dim), jnp.float32) * scale,
                "wo": jax.random.normal(k0[3], (dim, dim), jnp.float32) * scale,
                "w_up": jax.random.normal(k0[4], (dim, mlp_ratio * dim), jnp.float32)
                * scale,
                "w_down": jax.random.normal(k0[5], (mlp_ratio * dim, dim), jnp.float32)
                * (mlp_ratio * dim) ** -0.5,
                "norm1": jnp.ones((dim,), jnp.float32),
                "norm2": jnp.ones((dim,), jnp.float32),
            }
        )
    return params


def _rmsnorm(x, w):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6) * w


def transformer_forward(params, tokens, *, heads, causal=True):
    """Forward pass of the tiny LM: (batch, seq) int32 -> logits.

    Attention runs through the flash Pallas kernel — the same code path
    the paper's generated operators take.
    """
    x = params["embed"][tokens]  # (b, s, dim)
    b, s, dim = x.shape
    hd = dim // heads
    for lp in params["layers"]:
        h = _rmsnorm(x, lp["norm1"])
        q = (h @ lp["wq"]).reshape(b, s, heads, hd).transpose(0, 2, 1, 3)
        k = (h @ lp["wk"]).reshape(b, s, heads, hd).transpose(0, 2, 1, 3)
        v = (h @ lp["wv"]).reshape(b, s, heads, hd).transpose(0, 2, 1, 3)
        o = flash.flash_attention(
            q, k, v, causal=causal, bm=min(128, s), bn=min(64, s), interpret=True
        )
        o = o.transpose(0, 2, 1, 3).reshape(b, s, dim)
        x = x + o @ lp["wo"]
        h = _rmsnorm(x, lp["norm2"])
        x = x + jax.nn.gelu(h @ lp["w_up"]) @ lp["w_down"]
    x = _rmsnorm(x, params["out_norm"])
    return x @ params["embed"].T  # tied logits


def transformer_forward_ref(params, tokens, *, heads, causal=True):
    """Same forward with the jnp reference attention — the oracle used to
    validate the kernel-backed forward."""
    x = params["embed"][tokens]
    b, s, dim = x.shape
    hd = dim // heads
    for lp in params["layers"]:
        h = _rmsnorm(x, lp["norm1"])
        q = (h @ lp["wq"]).reshape(b, s, heads, hd).transpose(0, 2, 1, 3)
        k = (h @ lp["wk"]).reshape(b, s, heads, hd).transpose(0, 2, 1, 3)
        v = (h @ lp["wv"]).reshape(b, s, heads, hd).transpose(0, 2, 1, 3)
        o = ref.attention_ref(q, k, v, causal=causal)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, dim)
        x = x + o @ lp["wo"]
        h = _rmsnorm(x, lp["norm2"])
        x = x + jax.nn.gelu(h @ lp["w_up"]) @ lp["w_down"]
    x = _rmsnorm(x, params["out_norm"])
    return x @ params["embed"].T


def tiny_lm_fn(*, vocab=512, dim=128, heads=4, layers=2, seed=0):
    """A closed-over tiny-LM forward suitable for AOT lowering: weights are
    constants inside the jitted function; the only runtime input is the
    token batch."""
    params = make_params(
        jax.random.PRNGKey(seed), vocab=vocab, dim=dim, heads=heads, layers=layers
    )

    @functools.partial(jax.jit)
    def fn(tokens):
        return (transformer_forward(params, tokens, heads=heads),)

    return fn
